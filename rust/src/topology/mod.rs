//! First-class machine topology for the cloud/edge/device continuum.
//!
//! The paper frames ICU workload allocation as general unrelated-parallel-
//! machine scheduling (§V, citing [3][35]) but experiments with the
//! degenerate 1-cloud + 1-edge configuration (assumption (d)).  This module
//! is the single source of truth for the machine set: a [`Topology`] names
//! how many replicas each shared class has — and how fast each one is —
//! and a [`MachineRef`] names one concrete machine (class + replica).
//! Every scheduler core and the serving coordinator are parameterized by
//! it; [`Topology::paper`] reproduces the paper's setup bit-for-bit.
//!
//! Machines are truly *unrelated*: besides the per-class timing model
//! (transmission costs stay per-class — the network path is shared by the
//! class), every shared replica carries its own **speed factor**
//! ([`Topology::speed`], default 1.0).  A replica's effective processing
//! time is `ceil(I_i / speed)` ([`Topology::scaled_processing`]), so a
//! `speed` of 2.0 models a box twice as fast as the class's calibrated
//! machine and 0.5 a box half as fast.  All-1.0 topologies are bit-for-bit
//! identical to the per-class model (the `p / 1.0` division is exact), so
//! the paper's published numbers are unchanged.  The per-patient end
//! device is never shared and never scaled: it is modeled as a single
//! pseudo-replica (speed 1.0) whose queue never forms.
//!
//! # Invariant
//!
//! A validated `Topology` ([`Topology::try_new`], [`Topology::validate`])
//! always has **at least one replica of every class**: `clouds >= 1`,
//! `edges >= 1`, and the device pseudo-replica always exists.  Downstream
//! code (e.g. the serving router's replica selection) relies on this to
//! stay infallible — `machines()` and each class's replica range are
//! never empty.  Speed factors are validated finite and within
//! [`Topology::SPEED_RANGE`], so speed-scaled arithmetic can never
//! overflow or produce NaN orderings.

use crate::device::Layer;
use crate::serialize::Value;
use crate::simulation::Tick;
use crate::{Error, Result};

/// A machine *class* in the unrelated-parallel-machine system.
///
/// `Device` is the *releasing patient's own* bedside device — each job has
/// exactly one, so devices never queue across jobs (paper §VI: "the end
/// device is not the shared machine").
#[derive(
    Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord,
)]
pub enum MachineId {
    Cloud,
    Edge,
    Device,
}

impl MachineId {
    pub const ALL: [MachineId; 3] =
        [MachineId::Cloud, MachineId::Edge, MachineId::Device];

    /// The corresponding hierarchy layer.
    pub fn layer(self) -> Layer {
        match self {
            MachineId::Cloud => Layer::Cloud,
            MachineId::Edge => Layer::Edge,
            MachineId::Device => Layer::Device,
        }
    }

    pub fn from_layer(layer: Layer) -> Self {
        match layer {
            Layer::Cloud => MachineId::Cloud,
            Layer::Edge => MachineId::Edge,
            Layer::Device => MachineId::Device,
        }
    }
}

impl std::fmt::Display for MachineId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            MachineId::Cloud => "Cloud",
            MachineId::Edge => "Edge",
            MachineId::Device => "Device",
        })
    }
}

/// One concrete machine: a class plus a replica index within that class.
///
/// Replica indices are dense (`0..topology.replicas(class)`).  The device
/// pseudo-replica is always `replica == 0`; the job's own device is
/// implied by the job, not by the index.
///
/// The derived `Ord` (class-major, replica-minor) is the canonical
/// dispatch/move order everywhere: cloud replicas first, then edge
/// replicas, then the device — the paper's CC/ES/ED machine order, which
/// keeps every tie-break identical to the pre-topology scheduler.
#[derive(
    Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord,
)]
pub struct MachineRef {
    pub class: MachineId,
    pub replica: usize,
}

impl MachineRef {
    /// The (only) device pseudo-replica.
    pub const DEVICE: MachineRef =
        MachineRef { class: MachineId::Device, replica: 0 };

    pub fn cloud(replica: usize) -> Self {
        MachineRef { class: MachineId::Cloud, replica }
    }

    pub fn edge(replica: usize) -> Self {
        MachineRef { class: MachineId::Edge, replica }
    }

    pub fn device() -> Self {
        Self::DEVICE
    }

    /// The hierarchy layer of this machine's class.
    pub fn layer(self) -> Layer {
        self.class.layer()
    }

    /// Whether the machine is shared across jobs (cloud/edge replicas are;
    /// the per-patient device is not).
    pub fn is_shared(self) -> bool {
        self.class != MachineId::Device
    }

    /// Short label for thread names and reports (`CC0`, `ES1`, `ED`).
    pub fn label(self) -> String {
        match self.class {
            MachineId::Device => self.layer().abbrev().to_string(),
            _ => format!("{}{}", self.layer().abbrev(), self.replica),
        }
    }
}

impl std::fmt::Display for MachineRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // replica 0 prints as the bare class so paper-topology output is
        // unchanged; extra replicas disambiguate ("Edge:1")
        if self.replica == 0 {
            write!(f, "{}", self.class)
        } else {
            write!(f, "{}:{}", self.class, self.replica)
        }
    }
}

/// The machine set: `clouds` cloud servers + `edges` edge servers, each
/// with its own speed factor, plus the per-patient end devices (always
/// available, never shared).
///
/// Constructed homogeneous via [`Topology::new`] / [`Topology::try_new`]
/// (every replica at speed 1.0 — the paper's assumption (c)) or
/// heterogeneous via [`Topology::heterogeneous`] /
/// [`Topology::with_speeds`].  See the module docs for the ≥1-replica
/// invariant validated constructors guarantee.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    pub clouds: usize,
    pub edges: usize,
    /// Per-shared-replica speed factors in canonical order (cloud
    /// replicas, then edge replicas).  Canonical form: empty means every
    /// replica runs at 1.0 (constructors normalize an explicit all-1.0
    /// vector to empty, so `PartialEq`/`Hash` never distinguish the two).
    speeds: Vec<f64>,
}

// Speeds are validated finite (never NaN), so the partial equivalence is
// total and `Eq` is sound.
impl Eq for Topology {}

impl std::hash::Hash for Topology {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        use std::hash::Hash;
        self.clouds.hash(state);
        self.edges.hash(state);
        for s in &self.speeds {
            s.to_bits().hash(state);
        }
    }
}

impl Default for Topology {
    fn default() -> Self {
        Topology::paper()
    }
}

impl Topology {
    /// Accepted speed-factor range (a factor outside ±64× of the
    /// calibrated class machine is almost certainly a config typo, and
    /// the bound keeps `ceil(p / speed)` far from overflow).
    pub const SPEED_RANGE: std::ops::RangeInclusive<f64> =
        0.015625..=64.0;

    /// Construct a homogeneous topology without validation (infallible,
    /// for literals known to be sane).  Degenerate replica counts only
    /// surface when a scheduler core is reached, so prefer
    /// [`Topology::try_new`] on any path that takes user input — it
    /// rejects them up front with [`Error::InvalidTopology`].
    pub fn new(clouds: usize, edges: usize) -> Self {
        Topology { clouds, edges, speeds: Vec::new() }
    }

    /// Validated homogeneous construction: the front-door constructor for
    /// config, CLI, and [`crate::scenario`] input.  `try_new(0, _)` /
    /// `try_new(_, 0)` return [`Error::InvalidTopology`] instead of
    /// panicking later inside `simulate`; the result upholds the
    /// ≥1-replica invariant documented on the module.
    pub fn try_new(clouds: usize, edges: usize) -> Result<Self> {
        let t = Topology::new(clouds, edges);
        t.validate()?;
        Ok(t)
    }

    /// Validated heterogeneous construction: replica counts are the
    /// speed-vector lengths.  Speeds must be finite and inside
    /// [`Topology::SPEED_RANGE`].
    pub fn heterogeneous(
        cloud_speeds: Vec<f64>,
        edge_speeds: Vec<f64>,
    ) -> Result<Self> {
        let clouds = cloud_speeds.len();
        let edges = edge_speeds.len();
        Topology::with_speeds(
            clouds,
            edges,
            Some(cloud_speeds),
            Some(edge_speeds),
        )
    }

    /// Validated construction with optional per-class speed vectors
    /// (`None` = every replica of that class at 1.0).  A provided
    /// vector's length must equal the class's replica count.
    pub fn with_speeds(
        clouds: usize,
        edges: usize,
        cloud_speeds: Option<Vec<f64>>,
        edge_speeds: Option<Vec<f64>>,
    ) -> Result<Self> {
        let invalid = |reason: String| Error::InvalidTopology {
            clouds,
            edges,
            reason,
        };
        if let Some(cs) = &cloud_speeds {
            if cs.len() != clouds {
                return Err(invalid(format!(
                    "cloud_speeds has {} entries for {clouds} cloud \
                     replica(s)",
                    cs.len()
                )));
            }
        }
        if let Some(es) = &edge_speeds {
            if es.len() != edges {
                return Err(invalid(format!(
                    "edge_speeds has {} entries for {edges} edge \
                     replica(s)",
                    es.len()
                )));
            }
        }
        let mut speeds =
            cloud_speeds.unwrap_or_else(|| vec![1.0; clouds]);
        speeds.extend(edge_speeds.unwrap_or_else(|| vec![1.0; edges]));
        // canonical form: a fully-homogeneous vector is stored empty so
        // equality/hashing can't distinguish "unspecified" from "all 1.0"
        if speeds.iter().all(|&s| s == 1.0) {
            speeds.clear();
        }
        let t = Topology { clouds, edges, speeds };
        t.validate()?;
        Ok(t)
    }

    /// The paper's configuration: one cloud + one edge server
    /// (assumption (d)), both at unit speed (assumption (c)).
    pub fn paper() -> Self {
        Topology::new(1, 1)
    }

    pub fn is_paper(&self) -> bool {
        *self == Topology::paper()
    }

    /// Whether every replica runs at the class's calibrated speed
    /// (factor 1.0) — the regime where this topology is bit-for-bit
    /// equivalent to the per-class timing model.
    pub fn is_homogeneous(&self) -> bool {
        self.speeds.is_empty()
    }

    /// Compact label for reports and bench rows (`1c+2e`; heterogeneous
    /// topologies append the speed vector, e.g. `1c+2e speeds=[1,1.5,0.75]`).
    pub fn label(&self) -> String {
        if self.is_homogeneous() {
            format!("{}c+{}e", self.clouds, self.edges)
        } else {
            let speeds: Vec<String> =
                self.speeds.iter().map(|s| s.to_string()).collect();
            format!(
                "{}c+{}e speeds=[{}]",
                self.clouds,
                self.edges,
                speeds.join(",")
            )
        }
    }

    /// Number of shared machines (cloud + edge replicas).
    pub fn shared_count(&self) -> usize {
        self.clouds + self.edges
    }

    /// Number of dispatch lanes the serving coordinator runs: one per
    /// shared replica plus the device lane.
    pub fn lane_count(&self) -> usize {
        self.shared_count() + 1
    }

    /// Replicas of a class (the device counts as one pseudo-replica).
    pub fn replicas(&self, class: MachineId) -> usize {
        match class {
            MachineId::Cloud => self.clouds,
            MachineId::Edge => self.edges,
            MachineId::Device => 1,
        }
    }

    /// Whether a machine reference is valid in this topology.
    pub fn contains(&self, m: MachineRef) -> bool {
        m.replica < self.replicas(m.class)
    }

    /// The speed factor of one concrete machine (1.0 unless configured
    /// otherwise; the device pseudo-replica is always 1.0).
    pub fn speed(&self, m: MachineRef) -> f64 {
        match self.shared_index(m) {
            Some(s) => self.shared_speed(s),
            None => 1.0,
        }
    }

    /// The speed factor at a dense shared index (see
    /// [`Self::shared_index`]); allocation-free, for the simulator's hot
    /// loop.
    #[inline]
    pub fn shared_speed(&self, s: usize) -> f64 {
        self.speeds.get(s).copied().unwrap_or(1.0)
    }

    /// The cloud replicas' speed factors, materialized (length
    /// `clouds`; all 1.0 for a homogeneous class).
    pub fn cloud_speeds(&self) -> Vec<f64> {
        (0..self.clouds).map(|s| self.shared_speed(s)).collect()
    }

    /// The edge replicas' speed factors, materialized (length `edges`;
    /// all 1.0 for a homogeneous class).
    pub fn edge_speeds(&self) -> Vec<f64> {
        (self.clouds..self.shared_count())
            .map(|s| self.shared_speed(s))
            .collect()
    }

    /// A job's effective processing time on a concrete machine:
    /// `ceil(p / speed)` (a faster replica finishes sooner; ceil keeps
    /// C3's non-zero integer ticks).  At speed 1.0 this is exactly `p` —
    /// the guarantee behind the homogeneous bit-for-bit invariant.
    #[inline]
    pub fn scaled_processing(&self, p: Tick, m: MachineRef) -> Tick {
        match self.shared_index(m) {
            Some(s) => scale_ticks(p, self.shared_speed(s)),
            None => p,
        }
    }

    /// All machines in canonical order: `Cloud:0..c`, `Edge:0..e`,
    /// `Device`.  This is the scheduler's move/dispatch order and the
    /// coordinator's lane order.
    pub fn machines(&self) -> Vec<MachineRef> {
        let mut v = self.shared_machines();
        v.push(MachineRef::DEVICE);
        v
    }

    /// The machine at a dense lane index (inverse of [`Self::lane_index`];
    /// allocation-free, for per-request routing).
    ///
    /// # Panics
    /// Panics if `lane >= self.lane_count()`.
    pub fn machine_at(&self, lane: usize) -> MachineRef {
        if lane < self.clouds {
            MachineRef::cloud(lane)
        } else if lane < self.shared_count() {
            MachineRef::edge(lane - self.clouds)
        } else {
            assert!(lane == self.shared_count(), "lane {lane} out of range");
            MachineRef::DEVICE
        }
    }

    /// The shared machines only (no device), canonical order.
    pub fn shared_machines(&self) -> Vec<MachineRef> {
        let mut v: Vec<MachineRef> =
            (0..self.clouds).map(MachineRef::cloud).collect();
        v.extend((0..self.edges).map(MachineRef::edge));
        v
    }

    /// Dense index of a *shared* machine into per-replica state vectors
    /// (free-times, timelines, speeds); `None` for the device.
    pub fn shared_index(&self, m: MachineRef) -> Option<usize> {
        match m.class {
            MachineId::Cloud => Some(m.replica),
            MachineId::Edge => Some(self.clouds + m.replica),
            MachineId::Device => None,
        }
    }

    /// Dense lane index (shared replicas first, device last) — the
    /// serving coordinator's queue/engine indexing.
    pub fn lane_index(&self, m: MachineRef) -> usize {
        self.shared_index(m).unwrap_or(self.shared_count())
    }

    /// The `k`-th placement within a class, cycling over its replicas —
    /// how fixed-class strategies spread load (degenerates to replica 0
    /// in the paper topology).
    pub fn spread(&self, class: MachineId, k: usize) -> MachineRef {
        MachineRef { class, replica: k % self.replicas(class).max(1) }
    }

    pub fn validate(&self) -> Result<()> {
        let invalid = |reason: String| Error::InvalidTopology {
            clouds: self.clouds,
            edges: self.edges,
            reason,
        };
        if self.clouds == 0 || self.edges == 0 {
            return Err(invalid(
                "needs at least one cloud and one edge server".into(),
            ));
        }
        if self.shared_count() > 64 {
            return Err(invalid(format!(
                "{} shared machines; >64 is almost certainly a \
                 config typo",
                self.shared_count()
            )));
        }
        if !self.speeds.is_empty()
            && self.speeds.len() != self.shared_count()
        {
            return Err(invalid(format!(
                "{} speed factors for {} shared machines (construct \
                 through Topology::with_speeds)",
                self.speeds.len(),
                self.shared_count()
            )));
        }
        for (s, &f) in self.speeds.iter().enumerate() {
            if !f.is_finite() || !Self::SPEED_RANGE.contains(&f) {
                return Err(invalid(format!(
                    "speed factor {f} for shared machine {s} must be \
                     finite and within {:?}",
                    Self::SPEED_RANGE
                )));
            }
        }
        Ok(())
    }

    /// Parse from a config section, layered over the paper defaults.
    /// Replica counts default to the speed-vector lengths when only
    /// `cloud_speeds` / `edge_speeds` are given.
    pub fn from_reader(r: &crate::config::FieldReader) -> Result<Self> {
        let def = Topology::paper();
        let cloud_speeds = r.f64_list("cloud_speeds")?;
        let edge_speeds = r.f64_list("edge_speeds")?;
        let clouds = match r.usize("clouds")? {
            Some(c) => c,
            None => cloud_speeds
                .as_ref()
                .map(|v| v.len())
                .unwrap_or(def.clouds),
        };
        let edges = match r.usize("edges")? {
            Some(e) => e,
            None => edge_speeds
                .as_ref()
                .map(|v| v.len())
                .unwrap_or(def.edges),
        };
        r.finish()?;
        Topology::with_speeds(clouds, edges, cloud_speeds, edge_speeds)
    }

    /// Serialize as a config section (speed vectors are only emitted for
    /// heterogeneous classes, so homogeneous output is unchanged).
    pub fn to_value(&self) -> Value {
        let mut v = Value::object();
        v.set("clouds", self.clouds);
        v.set("edges", self.edges);
        if !self.is_homogeneous() {
            let cloud = self.cloud_speeds();
            let edge = self.edge_speeds();
            if cloud.iter().any(|&f| f != 1.0) {
                v.set("cloud_speeds", cloud);
            }
            if edge.iter().any(|&f| f != 1.0) {
                v.set("edge_speeds", edge);
            }
        }
        v
    }
}

/// `ceil(p / speed)` — the shared speed-scaling primitive (also the
/// contract `python/tools/suite_oracle.py` mirrors).  The `speed == 1.0`
/// fast path is what keeps homogeneous topologies bit-for-bit identical
/// to the per-class model.
#[inline]
pub fn scale_ticks(p: Tick, speed: f64) -> Tick {
    if speed == 1.0 {
        p
    } else {
        (p as f64 / speed).ceil() as Tick
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn machine_layer_roundtrip() {
        for m in MachineId::ALL {
            assert_eq!(MachineId::from_layer(m.layer()), m);
        }
    }

    #[test]
    fn paper_topology_machines_match_machine_id_order() {
        // the degenerate topology must enumerate exactly like the old
        // MachineId::ALL so every tie-break is preserved
        let ms = Topology::paper().machines();
        assert_eq!(
            ms,
            vec![
                MachineRef::cloud(0),
                MachineRef::edge(0),
                MachineRef::DEVICE
            ]
        );
        let classes: Vec<MachineId> = ms.iter().map(|m| m.class).collect();
        assert_eq!(classes, MachineId::ALL.to_vec());
    }

    #[test]
    fn machine_listing_and_indexing() {
        let t = Topology::new(2, 3);
        let ms = t.machines();
        assert_eq!(ms.len(), 6); // 2 + 3 + device
        assert_eq!(t.shared_count(), 5);
        assert_eq!(t.lane_count(), 6);
        for (i, &m) in t.shared_machines().iter().enumerate() {
            assert_eq!(t.shared_index(m), Some(i));
            assert_eq!(t.lane_index(m), i);
            assert!(t.contains(m));
        }
        // machine_at is the inverse of lane_index, in lane order
        for (lane, &m) in t.machines().iter().enumerate() {
            assert_eq!(t.machine_at(lane), m);
            assert_eq!(t.lane_index(t.machine_at(lane)), lane);
        }
        assert_eq!(t.shared_index(MachineRef::DEVICE), None);
        assert_eq!(t.lane_index(MachineRef::DEVICE), 5);
        assert!(!t.contains(MachineRef::cloud(2)));
        assert!(!t.contains(MachineRef::edge(3)));
        assert!(t.contains(MachineRef::DEVICE));
    }

    #[test]
    fn canonical_order_is_class_major() {
        let t = Topology::new(2, 2);
        let ms = t.machines();
        let mut sorted = ms.clone();
        sorted.sort_unstable();
        assert_eq!(ms, sorted, "machines() must already be in Ord order");
    }

    #[test]
    fn spread_cycles_replicas() {
        let t = Topology::new(1, 3);
        let picks: Vec<usize> = (0..6)
            .map(|k| t.spread(MachineId::Edge, k).replica)
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        // the paper topology degenerates to replica 0
        for k in 0..5 {
            assert_eq!(Topology::paper().spread(MachineId::Cloud, k).replica, 0);
        }
        // device is always the single pseudo-replica
        assert_eq!(t.spread(MachineId::Device, 7), MachineRef::DEVICE);
    }

    #[test]
    fn validation() {
        assert!(Topology::paper().validate().is_ok());
        assert!(Topology::new(0, 1).validate().is_err());
        assert!(Topology::new(1, 0).validate().is_err());
        assert!(Topology::new(1, 64).validate().is_err());
        assert!(Topology::new(2, 4).validate().is_ok());
    }

    #[test]
    fn try_new_returns_typed_error() {
        assert_eq!(Topology::try_new(1, 2).unwrap(), Topology::new(1, 2));
        for (c, e) in [(0usize, 1usize), (1, 0), (0, 0), (32, 33)] {
            match Topology::try_new(c, e) {
                Err(Error::InvalidTopology { clouds, edges, .. }) => {
                    assert_eq!((clouds, edges), (c, e));
                }
                other => panic!("expected InvalidTopology, got {other:?}"),
            }
        }
        // the message names the offending counts
        let msg = Topology::try_new(0, 3).unwrap_err().to_string();
        assert!(msg.contains("0c+3e"), "{msg}");
    }

    #[test]
    fn config_roundtrip() {
        let t = Topology::new(2, 3);
        let v = t.to_value();
        let r = crate::config::FieldReader::new(&v, "topology").unwrap();
        assert_eq!(Topology::from_reader(&r).unwrap(), t);
    }

    #[test]
    fn heterogeneous_config_roundtrip() {
        let t = Topology::heterogeneous(vec![2.0], vec![1.5, 0.75])
            .unwrap();
        let v = t.to_value();
        let r = crate::config::FieldReader::new(&v, "topology").unwrap();
        let back = Topology::from_reader(&r).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.speed(MachineRef::cloud(0)), 2.0);
        assert_eq!(back.speed(MachineRef::edge(1)), 0.75);
    }

    #[test]
    fn counts_inferred_from_speed_vectors() {
        let v = crate::serialize::toml::parse(
            "edge_speeds = [1.5, 0.75, 1.0]\n",
        )
        .unwrap();
        let r = crate::config::FieldReader::new(&v, "topology").unwrap();
        let t = Topology::from_reader(&r).unwrap();
        assert_eq!((t.clouds, t.edges), (1, 3));
        assert_eq!(t.speed(MachineRef::edge(0)), 1.5);
        // explicit mismatched count is a typed error
        let v = crate::serialize::toml::parse(
            "edges = 2\nedge_speeds = [1.5]\n",
        )
        .unwrap();
        let r = crate::config::FieldReader::new(&v, "topology").unwrap();
        assert!(matches!(
            Topology::from_reader(&r),
            Err(Error::InvalidTopology { .. })
        ));
    }

    #[test]
    fn speeds_default_to_unit_and_validate() {
        let t = Topology::new(2, 2);
        for m in t.machines() {
            assert_eq!(t.speed(m), 1.0, "{m}");
        }
        assert!(t.is_homogeneous());
        // explicit all-1.0 vectors normalize to the homogeneous form
        let explicit = Topology::with_speeds(
            2,
            2,
            Some(vec![1.0, 1.0]),
            Some(vec![1.0, 1.0]),
        )
        .unwrap();
        assert_eq!(explicit, t);
        assert!(explicit.is_homogeneous());
        // invalid factors are typed errors
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY, 1e9, 1e-9] {
            assert!(
                Topology::heterogeneous(vec![bad], vec![1.0]).is_err(),
                "{bad}"
            );
        }
        // wrong-length vectors are typed errors
        assert!(Topology::with_speeds(2, 1, Some(vec![1.5]), None)
            .is_err());
    }

    #[test]
    fn scaled_processing_ceil_and_identity() {
        let t = Topology::heterogeneous(vec![1.0], vec![2.0, 0.5])
            .unwrap();
        // unit speed: exact identity
        assert_eq!(t.scaled_processing(7, MachineRef::cloud(0)), 7);
        assert_eq!(t.scaled_processing(7, MachineRef::DEVICE), 7);
        // 2× faster: ceil(7/2) = 4
        assert_eq!(t.scaled_processing(7, MachineRef::edge(0)), 4);
        // 2× slower: 14
        assert_eq!(t.scaled_processing(7, MachineRef::edge(1)), 14);
        // C3: non-zero ticks survive scaling
        assert_eq!(t.scaled_processing(1, MachineRef::edge(0)), 1);
        assert_eq!(scale_ticks(9, 1.5), 6);
        assert_eq!(scale_ticks(10, 1.5), 7);
    }

    #[test]
    fn heterogeneous_identity_equality_and_hash() {
        use std::collections::HashSet;
        let a = Topology::heterogeneous(vec![1.0], vec![1.5]).unwrap();
        let b = Topology::heterogeneous(vec![1.0], vec![1.5]).unwrap();
        let c = Topology::new(1, 1);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(!a.is_paper());
        assert!(c.is_paper());
        let set: HashSet<Topology> =
            [a.clone(), b, c].into_iter().collect();
        assert_eq!(set.len(), 2);
        assert!(a.label().contains("speeds=[1,1.5]"), "{}", a.label());
        assert_eq!(Topology::new(1, 2).label(), "1c+2e");
    }

    #[test]
    fn display_keeps_paper_labels() {
        assert_eq!(MachineRef::cloud(0).to_string(), "Cloud");
        assert_eq!(MachineRef::edge(1).to_string(), "Edge:1");
        assert_eq!(MachineRef::DEVICE.to_string(), "Device");
        assert_eq!(MachineRef::edge(1).label(), "ES1");
        assert_eq!(MachineRef::DEVICE.label(), "ED");
    }
}
