//! First-class machine topology for the cloud/edge/device continuum.
//!
//! The paper frames ICU workload allocation as general unrelated-parallel-
//! machine scheduling (§V, citing [3][35]) but experiments with the
//! degenerate 1-cloud + 1-edge configuration (assumption (d)).  This module
//! is the single source of truth for the machine set: a [`Topology`] names
//! how many interchangeable replicas each shared class has, and a
//! [`MachineRef`] names one concrete machine (class + replica).  Every
//! scheduler core and the serving coordinator are parameterized by it;
//! [`Topology::paper`] reproduces the paper's setup bit-for-bit.
//!
//! Replicas of a class share the class's timing model (processing and
//! transmission costs are per-class, per assumption (c)); what a replica
//! adds is an independent exclusive execution timeline (constraint C1).
//! The per-patient end device is never shared, so it is modeled as a
//! single pseudo-replica whose queue never forms.

use crate::device::Layer;
use crate::serialize::Value;
use crate::{Error, Result};

/// A machine *class* in the unrelated-parallel-machine system.
///
/// `Device` is the *releasing patient's own* bedside device — each job has
/// exactly one, so devices never queue across jobs (paper §VI: "the end
/// device is not the shared machine").
#[derive(
    Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord,
)]
pub enum MachineId {
    Cloud,
    Edge,
    Device,
}

impl MachineId {
    pub const ALL: [MachineId; 3] =
        [MachineId::Cloud, MachineId::Edge, MachineId::Device];

    /// The corresponding hierarchy layer.
    pub fn layer(self) -> Layer {
        match self {
            MachineId::Cloud => Layer::Cloud,
            MachineId::Edge => Layer::Edge,
            MachineId::Device => Layer::Device,
        }
    }

    pub fn from_layer(layer: Layer) -> Self {
        match layer {
            Layer::Cloud => MachineId::Cloud,
            Layer::Edge => MachineId::Edge,
            Layer::Device => MachineId::Device,
        }
    }
}

impl std::fmt::Display for MachineId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            MachineId::Cloud => "Cloud",
            MachineId::Edge => "Edge",
            MachineId::Device => "Device",
        })
    }
}

/// One concrete machine: a class plus a replica index within that class.
///
/// Replica indices are dense (`0..topology.replicas(class)`).  The device
/// pseudo-replica is always `replica == 0`; the job's own device is
/// implied by the job, not by the index.
///
/// The derived `Ord` (class-major, replica-minor) is the canonical
/// dispatch/move order everywhere: cloud replicas first, then edge
/// replicas, then the device — the paper's CC/ES/ED machine order, which
/// keeps every tie-break identical to the pre-topology scheduler.
#[derive(
    Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord,
)]
pub struct MachineRef {
    pub class: MachineId,
    pub replica: usize,
}

impl MachineRef {
    /// The (only) device pseudo-replica.
    pub const DEVICE: MachineRef =
        MachineRef { class: MachineId::Device, replica: 0 };

    pub fn cloud(replica: usize) -> Self {
        MachineRef { class: MachineId::Cloud, replica }
    }

    pub fn edge(replica: usize) -> Self {
        MachineRef { class: MachineId::Edge, replica }
    }

    pub fn device() -> Self {
        Self::DEVICE
    }

    /// The hierarchy layer of this machine's class.
    pub fn layer(self) -> Layer {
        self.class.layer()
    }

    /// Whether the machine is shared across jobs (cloud/edge replicas are;
    /// the per-patient device is not).
    pub fn is_shared(self) -> bool {
        self.class != MachineId::Device
    }

    /// Short label for thread names and reports (`CC0`, `ES1`, `ED`).
    pub fn label(self) -> String {
        match self.class {
            MachineId::Device => self.layer().abbrev().to_string(),
            _ => format!("{}{}", self.layer().abbrev(), self.replica),
        }
    }
}

impl std::fmt::Display for MachineRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // replica 0 prints as the bare class so paper-topology output is
        // unchanged; extra replicas disambiguate ("Edge:1")
        if self.replica == 0 {
            write!(f, "{}", self.class)
        } else {
            write!(f, "{}:{}", self.class, self.replica)
        }
    }
}

/// The machine set: `clouds` cloud servers + `edges` edge servers, plus
/// the per-patient end devices (always available, never shared).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Topology {
    pub clouds: usize,
    pub edges: usize,
}

impl Default for Topology {
    fn default() -> Self {
        Topology::paper()
    }
}

impl Topology {
    /// Construct without validation (infallible, for literals known to be
    /// sane).  Degenerate replica counts only surface when a scheduler
    /// core is reached, so prefer [`Topology::try_new`] on any path that
    /// takes user input — it rejects them up front with
    /// [`Error::InvalidTopology`].
    pub fn new(clouds: usize, edges: usize) -> Self {
        Topology { clouds, edges }
    }

    /// Validated construction: the front-door constructor for config,
    /// CLI, and [`crate::scenario`] input.  `try_new(0, _)` /
    /// `try_new(_, 0)` return [`Error::InvalidTopology`] instead of
    /// panicking later inside `simulate`.
    pub fn try_new(clouds: usize, edges: usize) -> Result<Self> {
        let t = Topology { clouds, edges };
        t.validate()?;
        Ok(t)
    }

    /// The paper's configuration: one cloud + one edge server
    /// (assumption (d)).
    pub fn paper() -> Self {
        Topology { clouds: 1, edges: 1 }
    }

    pub fn is_paper(&self) -> bool {
        *self == Topology::paper()
    }

    /// Compact label for reports and bench rows (`1c+2e`).
    pub fn label(&self) -> String {
        format!("{}c+{}e", self.clouds, self.edges)
    }

    /// Number of shared machines (cloud + edge replicas).
    pub fn shared_count(&self) -> usize {
        self.clouds + self.edges
    }

    /// Number of dispatch lanes the serving coordinator runs: one per
    /// shared replica plus the device lane.
    pub fn lane_count(&self) -> usize {
        self.shared_count() + 1
    }

    /// Replicas of a class (the device counts as one pseudo-replica).
    pub fn replicas(&self, class: MachineId) -> usize {
        match class {
            MachineId::Cloud => self.clouds,
            MachineId::Edge => self.edges,
            MachineId::Device => 1,
        }
    }

    /// Whether a machine reference is valid in this topology.
    pub fn contains(&self, m: MachineRef) -> bool {
        m.replica < self.replicas(m.class)
    }

    /// All machines in canonical order: `Cloud:0..c`, `Edge:0..e`,
    /// `Device`.  This is the scheduler's move/dispatch order and the
    /// coordinator's lane order.
    pub fn machines(&self) -> Vec<MachineRef> {
        let mut v = self.shared_machines();
        v.push(MachineRef::DEVICE);
        v
    }

    /// The machine at a dense lane index (inverse of [`Self::lane_index`];
    /// allocation-free, for per-request routing).
    ///
    /// # Panics
    /// Panics if `lane >= self.lane_count()`.
    pub fn machine_at(&self, lane: usize) -> MachineRef {
        if lane < self.clouds {
            MachineRef::cloud(lane)
        } else if lane < self.shared_count() {
            MachineRef::edge(lane - self.clouds)
        } else {
            assert!(lane == self.shared_count(), "lane {lane} out of range");
            MachineRef::DEVICE
        }
    }

    /// The shared machines only (no device), canonical order.
    pub fn shared_machines(&self) -> Vec<MachineRef> {
        let mut v: Vec<MachineRef> =
            (0..self.clouds).map(MachineRef::cloud).collect();
        v.extend((0..self.edges).map(MachineRef::edge));
        v
    }

    /// Dense index of a *shared* machine into per-replica state vectors
    /// (free-times, timelines); `None` for the device.
    pub fn shared_index(&self, m: MachineRef) -> Option<usize> {
        match m.class {
            MachineId::Cloud => Some(m.replica),
            MachineId::Edge => Some(self.clouds + m.replica),
            MachineId::Device => None,
        }
    }

    /// Dense lane index (shared replicas first, device last) — the
    /// serving coordinator's queue/engine indexing.
    pub fn lane_index(&self, m: MachineRef) -> usize {
        self.shared_index(m).unwrap_or(self.shared_count())
    }

    /// The `k`-th placement within a class, cycling over its replicas —
    /// how fixed-class strategies spread load (degenerates to replica 0
    /// in the paper topology).
    pub fn spread(&self, class: MachineId, k: usize) -> MachineRef {
        MachineRef { class, replica: k % self.replicas(class).max(1) }
    }

    pub fn validate(&self) -> Result<()> {
        if self.clouds == 0 || self.edges == 0 {
            return Err(Error::InvalidTopology {
                clouds: self.clouds,
                edges: self.edges,
                reason: "needs at least one cloud and one edge server"
                    .into(),
            });
        }
        if self.shared_count() > 64 {
            return Err(Error::InvalidTopology {
                clouds: self.clouds,
                edges: self.edges,
                reason: format!(
                    "{} shared machines; >64 is almost certainly a \
                     config typo",
                    self.shared_count()
                ),
            });
        }
        Ok(())
    }

    /// Parse from a config section, layered over the paper defaults.
    pub fn from_reader(r: &crate::config::FieldReader) -> Result<Self> {
        let def = Topology::paper();
        let t = Topology {
            clouds: r.usize("clouds")?.unwrap_or(def.clouds),
            edges: r.usize("edges")?.unwrap_or(def.edges),
        };
        r.finish()?;
        t.validate()?;
        Ok(t)
    }

    /// Serialize as a config section.
    pub fn to_value(&self) -> Value {
        let mut v = Value::object();
        v.set("clouds", self.clouds);
        v.set("edges", self.edges);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn machine_layer_roundtrip() {
        for m in MachineId::ALL {
            assert_eq!(MachineId::from_layer(m.layer()), m);
        }
    }

    #[test]
    fn paper_topology_machines_match_machine_id_order() {
        // the degenerate topology must enumerate exactly like the old
        // MachineId::ALL so every tie-break is preserved
        let ms = Topology::paper().machines();
        assert_eq!(
            ms,
            vec![
                MachineRef::cloud(0),
                MachineRef::edge(0),
                MachineRef::DEVICE
            ]
        );
        let classes: Vec<MachineId> = ms.iter().map(|m| m.class).collect();
        assert_eq!(classes, MachineId::ALL.to_vec());
    }

    #[test]
    fn machine_listing_and_indexing() {
        let t = Topology::new(2, 3);
        let ms = t.machines();
        assert_eq!(ms.len(), 6); // 2 + 3 + device
        assert_eq!(t.shared_count(), 5);
        assert_eq!(t.lane_count(), 6);
        for (i, &m) in t.shared_machines().iter().enumerate() {
            assert_eq!(t.shared_index(m), Some(i));
            assert_eq!(t.lane_index(m), i);
            assert!(t.contains(m));
        }
        // machine_at is the inverse of lane_index, in lane order
        for (lane, &m) in t.machines().iter().enumerate() {
            assert_eq!(t.machine_at(lane), m);
            assert_eq!(t.lane_index(t.machine_at(lane)), lane);
        }
        assert_eq!(t.shared_index(MachineRef::DEVICE), None);
        assert_eq!(t.lane_index(MachineRef::DEVICE), 5);
        assert!(!t.contains(MachineRef::cloud(2)));
        assert!(!t.contains(MachineRef::edge(3)));
        assert!(t.contains(MachineRef::DEVICE));
    }

    #[test]
    fn canonical_order_is_class_major() {
        let t = Topology::new(2, 2);
        let ms = t.machines();
        let mut sorted = ms.clone();
        sorted.sort_unstable();
        assert_eq!(ms, sorted, "machines() must already be in Ord order");
    }

    #[test]
    fn spread_cycles_replicas() {
        let t = Topology::new(1, 3);
        let picks: Vec<usize> = (0..6)
            .map(|k| t.spread(MachineId::Edge, k).replica)
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        // the paper topology degenerates to replica 0
        for k in 0..5 {
            assert_eq!(Topology::paper().spread(MachineId::Cloud, k).replica, 0);
        }
        // device is always the single pseudo-replica
        assert_eq!(t.spread(MachineId::Device, 7), MachineRef::DEVICE);
    }

    #[test]
    fn validation() {
        assert!(Topology::paper().validate().is_ok());
        assert!(Topology::new(0, 1).validate().is_err());
        assert!(Topology::new(1, 0).validate().is_err());
        assert!(Topology::new(1, 64).validate().is_err());
        assert!(Topology::new(2, 4).validate().is_ok());
    }

    #[test]
    fn try_new_returns_typed_error() {
        assert_eq!(Topology::try_new(1, 2).unwrap(), Topology::new(1, 2));
        for (c, e) in [(0usize, 1usize), (1, 0), (0, 0), (32, 33)] {
            match Topology::try_new(c, e) {
                Err(Error::InvalidTopology { clouds, edges, .. }) => {
                    assert_eq!((clouds, edges), (c, e));
                }
                other => panic!("expected InvalidTopology, got {other:?}"),
            }
        }
        // the message names the offending counts
        let msg = Topology::try_new(0, 3).unwrap_err().to_string();
        assert!(msg.contains("0c+3e"), "{msg}");
    }

    #[test]
    fn config_roundtrip() {
        let t = Topology::new(2, 3);
        let v = t.to_value();
        let r = crate::config::FieldReader::new(&v, "topology").unwrap();
        assert_eq!(Topology::from_reader(&r).unwrap(), t);
    }

    #[test]
    fn display_keeps_paper_labels() {
        assert_eq!(MachineRef::cloud(0).to_string(), "Cloud");
        assert_eq!(MachineRef::edge(1).to_string(), "Edge:1");
        assert_eq!(MachineRef::DEVICE.to_string(), "Device");
        assert_eq!(MachineRef::edge(1).label(), "ES1");
        assert_eq!(MachineRef::DEVICE.label(), "ED");
    }
}
