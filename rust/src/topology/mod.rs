//! First-class machine topology for the cloud/edge/device continuum.
//!
//! The paper frames ICU workload allocation as general unrelated-parallel-
//! machine scheduling (§V, citing [3][35]) but experiments with the
//! degenerate 1-cloud + 1-edge configuration (assumption (d)).  This module
//! is the single source of truth for the machine set: a [`Topology`] names
//! how many replicas each shared class has — and how fast each one is —
//! and a [`MachineRef`] names one concrete machine (class + replica).
//! Every scheduler core and the serving coordinator are parameterized by
//! it; [`Topology::paper`] reproduces the paper's setup bit-for-bit.
//!
//! Machines are truly *unrelated*: every shared replica carries its own
//! **speed factor** ([`Topology::speed`], default 1.0) *and* its own
//! **link factor** ([`Topology::link`], default 1.0).  A replica's
//! effective processing time is `ceil(I_i / speed)`
//! ([`Topology::scaled_processing`]) — a `speed` of 2.0 models a box
//! twice as fast as the class's calibrated machine — and its effective
//! transmission time is `ceil(D_i / link)`
//! ([`Topology::scaled_transmission`]) — a `link` of 0.5 models a
//! gateway on Wi-Fi reaching the class's network path at half the rate,
//! 2.0 a replica on a premium uplink.  All-1.0 topologies are
//! bit-for-bit identical to the per-class model (the `x / 1.0` division
//! is exact), so the paper's published numbers are unchanged.  The
//! per-patient end device is never shared and never scaled: it is
//! modeled as a single pseudo-replica (speed and link 1.0) whose queue
//! never forms and which transmits nothing (assumption (a)).
//!
//! # Invariant
//!
//! A validated `Topology` ([`Topology::try_new`], [`Topology::validate`])
//! always has **at least one edge replica** (`edges >= 1`) and the device
//! pseudo-replica.  The *cloud* class, uniquely, may be empty
//! (`clouds == 0`): a metro ward granted no share of the shared cloud
//! tier (see [`crate::metro`]) schedules against an edge-only pool.
//! `machines()` is therefore never empty, and fixed-class strategies
//! that target an empty class fall back to the device
//! ([`Topology::spread`]).  The serving coordinator additionally
//! requires `clouds >= 1` (`ServeConfig::validate`) so the three-layer
//! request path keeps at least one lane per layer.  Speed and link
//! factors are validated finite and within [`Topology::SPEED_RANGE`] /
//! [`Topology::LINK_RANGE`], so factor-scaled arithmetic can never
//! overflow or produce NaN orderings.

use crate::device::Layer;
use crate::serialize::Value;
use crate::simulation::Tick;
use crate::{Error, Result};

/// A machine *class* in the unrelated-parallel-machine system.
///
/// `Device` is the *releasing patient's own* bedside device — each job has
/// exactly one, so devices never queue across jobs (paper §VI: "the end
/// device is not the shared machine").
#[derive(
    Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord,
)]
pub enum MachineId {
    Cloud,
    Edge,
    Device,
}

impl MachineId {
    pub const ALL: [MachineId; 3] =
        [MachineId::Cloud, MachineId::Edge, MachineId::Device];

    /// The corresponding hierarchy layer.
    pub fn layer(self) -> Layer {
        match self {
            MachineId::Cloud => Layer::Cloud,
            MachineId::Edge => Layer::Edge,
            MachineId::Device => Layer::Device,
        }
    }

    pub fn from_layer(layer: Layer) -> Self {
        match layer {
            Layer::Cloud => MachineId::Cloud,
            Layer::Edge => MachineId::Edge,
            Layer::Device => MachineId::Device,
        }
    }
}

impl std::fmt::Display for MachineId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            MachineId::Cloud => "Cloud",
            MachineId::Edge => "Edge",
            MachineId::Device => "Device",
        })
    }
}

/// One concrete machine: a class plus a replica index within that class.
///
/// Replica indices are dense (`0..topology.replicas(class)`).  The device
/// pseudo-replica is always `replica == 0`; the job's own device is
/// implied by the job, not by the index.
///
/// The derived `Ord` (class-major, replica-minor) is the canonical
/// dispatch/move order everywhere: cloud replicas first, then edge
/// replicas, then the device — the paper's CC/ES/ED machine order, which
/// keeps every tie-break identical to the pre-topology scheduler.
#[derive(
    Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord,
)]
pub struct MachineRef {
    pub class: MachineId,
    pub replica: usize,
}

impl MachineRef {
    /// The (only) device pseudo-replica.
    pub const DEVICE: MachineRef =
        MachineRef { class: MachineId::Device, replica: 0 };

    pub fn cloud(replica: usize) -> Self {
        MachineRef { class: MachineId::Cloud, replica }
    }

    pub fn edge(replica: usize) -> Self {
        MachineRef { class: MachineId::Edge, replica }
    }

    pub fn device() -> Self {
        Self::DEVICE
    }

    /// The hierarchy layer of this machine's class.
    pub fn layer(self) -> Layer {
        self.class.layer()
    }

    /// Whether the machine is shared across jobs (cloud/edge replicas are;
    /// the per-patient device is not).
    pub fn is_shared(self) -> bool {
        self.class != MachineId::Device
    }

    /// Short label for thread names and reports (`CC0`, `ES1`, `ED`).
    pub fn label(self) -> String {
        match self.class {
            MachineId::Device => self.layer().abbrev().to_string(),
            _ => format!("{}{}", self.layer().abbrev(), self.replica),
        }
    }
}

impl std::fmt::Display for MachineRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // replica 0 prints as the bare class so paper-topology output is
        // unchanged; extra replicas disambiguate ("Edge:1")
        if self.replica == 0 {
            write!(f, "{}", self.class)
        } else {
            write!(f, "{}:{}", self.class, self.replica)
        }
    }
}

/// The machine set: `clouds` cloud servers + `edges` edge servers, each
/// with its own speed and link factor, plus the per-patient end devices
/// (always available, never shared).
///
/// Constructed homogeneous via [`Topology::new`] / [`Topology::try_new`]
/// (every replica at speed and link 1.0 — the paper's assumptions (b)
/// and (c)) or heterogeneous via [`Topology::heterogeneous`] /
/// [`Topology::with_speeds`] / [`Topology::with_links`] /
/// [`Topology::with_factors`].  See the module docs for the ≥1-replica
/// invariant validated constructors guarantee.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    pub clouds: usize,
    pub edges: usize,
    /// Per-shared-replica speed factors in canonical order (cloud
    /// replicas, then edge replicas).  Canonical form: empty means every
    /// replica runs at 1.0 (constructors normalize an explicit all-1.0
    /// vector to empty, so `PartialEq`/`Hash` never distinguish the two).
    speeds: Vec<f64>,
    /// Per-shared-replica link factors, same canonical order and same
    /// empty-means-all-1.0 canonical form as `speeds`.
    links: Vec<f64>,
}

// Speeds and links are validated finite (never NaN), so the partial
// equivalence is total and `Eq` is sound.
impl Eq for Topology {}

impl std::hash::Hash for Topology {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        use std::hash::Hash;
        self.clouds.hash(state);
        self.edges.hash(state);
        // length-prefix each axis so a speeds-only and a links-only
        // topology carrying the same factor vector hash differently
        self.speeds.len().hash(state);
        for s in &self.speeds {
            s.to_bits().hash(state);
        }
        self.links.len().hash(state);
        for l in &self.links {
            l.to_bits().hash(state);
        }
    }
}

impl Default for Topology {
    fn default() -> Self {
        Topology::paper()
    }
}

impl Topology {
    /// Accepted speed-factor range (a factor outside ±64× of the
    /// calibrated class machine is almost certainly a config typo, and
    /// the bound keeps `ceil(p / speed)` far from overflow).
    pub const SPEED_RANGE: std::ops::RangeInclusive<f64> =
        0.015625..=64.0;

    /// Accepted link-factor range (same rationale and bounds as
    /// [`Topology::SPEED_RANGE`]: ±64× of the class's network path).
    pub const LINK_RANGE: std::ops::RangeInclusive<f64> =
        0.015625..=64.0;

    /// Most shared machines (cloud + edge replicas) a topology may
    /// hold; more is almost certainly a config typo, and the bound
    /// keeps per-replica bookkeeping cheap.  [`crate::metro`] checks
    /// fused ward topologies against the same limit up front.
    pub const MAX_SHARED: usize = 64;

    /// Construct a homogeneous topology without validation (infallible,
    /// for literals known to be sane).  Degenerate replica counts only
    /// surface when a scheduler core is reached, so prefer
    /// [`Topology::try_new`] on any path that takes user input — it
    /// rejects them up front with [`Error::InvalidTopology`].
    pub fn new(clouds: usize, edges: usize) -> Self {
        Topology { clouds, edges, speeds: Vec::new(), links: Vec::new() }
    }

    /// Validated homogeneous construction: the front-door constructor for
    /// config, CLI, and [`crate::scenario`] input.  `try_new(_, 0)`
    /// returns [`Error::InvalidTopology`] instead of panicking later
    /// inside `simulate`; `try_new(0, e)` is a valid edge-only pool (a
    /// metro ward granted no cloud share).  The result upholds the
    /// invariant documented on the module.
    pub fn try_new(clouds: usize, edges: usize) -> Result<Self> {
        let t = Topology::new(clouds, edges);
        t.validate()?;
        Ok(t)
    }

    /// Validated heterogeneous construction: replica counts are the
    /// speed-vector lengths.  Speeds must be finite and inside
    /// [`Topology::SPEED_RANGE`].
    pub fn heterogeneous(
        cloud_speeds: Vec<f64>,
        edge_speeds: Vec<f64>,
    ) -> Result<Self> {
        let clouds = cloud_speeds.len();
        let edges = edge_speeds.len();
        Topology::with_speeds(
            clouds,
            edges,
            Some(cloud_speeds),
            Some(edge_speeds),
        )
    }

    /// Validated construction with optional per-class speed vectors
    /// (`None` = every replica of that class at 1.0).  A provided
    /// vector's length must equal the class's replica count.
    pub fn with_speeds(
        clouds: usize,
        edges: usize,
        cloud_speeds: Option<Vec<f64>>,
        edge_speeds: Option<Vec<f64>>,
    ) -> Result<Self> {
        Topology::with_factors(
            clouds,
            edges,
            cloud_speeds,
            edge_speeds,
            None,
            None,
        )
    }

    /// Validated construction with optional per-class *link* vectors
    /// (`None` = every replica of that class reaches the network at the
    /// class rate, factor 1.0) — the network mirror of
    /// [`Topology::with_speeds`].
    pub fn with_links(
        clouds: usize,
        edges: usize,
        cloud_links: Option<Vec<f64>>,
        edge_links: Option<Vec<f64>>,
    ) -> Result<Self> {
        Topology::with_factors(
            clouds,
            edges,
            None,
            None,
            cloud_links,
            edge_links,
        )
    }

    /// Fully-general validated construction: optional per-class speed
    /// *and* link vectors (`None` = all 1.0 for that class and axis).
    /// Every provided vector's length must equal the class's replica
    /// count.
    pub fn with_factors(
        clouds: usize,
        edges: usize,
        cloud_speeds: Option<Vec<f64>>,
        edge_speeds: Option<Vec<f64>>,
        cloud_links: Option<Vec<f64>>,
        edge_links: Option<Vec<f64>>,
    ) -> Result<Self> {
        let invalid = |reason: String| Error::InvalidTopology {
            clouds,
            edges,
            reason,
        };
        let check_len = |v: &Option<Vec<f64>>,
                         field: &str,
                         want: usize,
                         class: &str|
         -> Result<()> {
            if let Some(v) = v {
                if v.len() != want {
                    return Err(invalid(format!(
                        "{field} has {} entries for {want} {class} \
                         replica(s)",
                        v.len()
                    )));
                }
            }
            Ok(())
        };
        check_len(&cloud_speeds, "cloud_speeds", clouds, "cloud")?;
        check_len(&edge_speeds, "edge_speeds", edges, "edge")?;
        check_len(&cloud_links, "cloud_links", clouds, "cloud")?;
        check_len(&edge_links, "edge_links", edges, "edge")?;
        // canonical form: a fully-homogeneous vector is stored empty so
        // equality/hashing can't distinguish "unspecified" from "all 1.0"
        let canonical = |cloud: Option<Vec<f64>>,
                         edge: Option<Vec<f64>>|
         -> Vec<f64> {
            let mut v = cloud.unwrap_or_else(|| vec![1.0; clouds]);
            v.extend(edge.unwrap_or_else(|| vec![1.0; edges]));
            // analysis: allow(float-eq, "unit factors are exact sentinels: 1.0 is stored verbatim, never computed")
            if v.iter().all(|&f| f == 1.0) {
                v.clear();
            }
            v
        };
        let speeds = canonical(cloud_speeds, edge_speeds);
        let links = canonical(cloud_links, edge_links);
        let t = Topology { clouds, edges, speeds, links };
        t.validate()?;
        Ok(t)
    }

    /// The paper's configuration: one cloud + one edge server
    /// (assumption (d)), both at unit speed and link (assumptions (b)
    /// and (c)).
    pub fn paper() -> Self {
        Topology::new(1, 1)
    }

    pub fn is_paper(&self) -> bool {
        *self == Topology::paper()
    }

    /// Whether every replica runs at the class's calibrated speed *and*
    /// reaches the network at the class rate (both factors 1.0) — the
    /// regime where this topology is bit-for-bit equivalent to the
    /// per-class timing model.
    pub fn is_homogeneous(&self) -> bool {
        self.speeds.is_empty() && self.links.is_empty()
    }

    /// Compact label for reports and bench rows (`1c+2e`; heterogeneous
    /// topologies append the non-unit factor vectors, e.g.
    /// `1c+2e speeds=[1,1.5,0.75]` or `1c+2e links=[1,0.5,1]`).
    pub fn label(&self) -> String {
        let mut label = format!("{}c+{}e", self.clouds, self.edges);
        let join = |v: &[f64]| {
            v.iter()
                .map(|f| f.to_string())
                .collect::<Vec<_>>()
                .join(",")
        };
        if !self.speeds.is_empty() {
            label.push_str(&format!(" speeds=[{}]", join(&self.speeds)));
        }
        if !self.links.is_empty() {
            label.push_str(&format!(" links=[{}]", join(&self.links)));
        }
        label
    }

    /// Number of shared machines (cloud + edge replicas).
    pub fn shared_count(&self) -> usize {
        self.clouds + self.edges
    }

    /// Number of dispatch lanes the serving coordinator runs: one per
    /// shared replica plus the device lane.
    pub fn lane_count(&self) -> usize {
        self.shared_count() + 1
    }

    /// Replicas of a class (the device counts as one pseudo-replica).
    pub fn replicas(&self, class: MachineId) -> usize {
        match class {
            MachineId::Cloud => self.clouds,
            MachineId::Edge => self.edges,
            MachineId::Device => 1,
        }
    }

    /// Whether a machine reference is valid in this topology.
    pub fn contains(&self, m: MachineRef) -> bool {
        m.replica < self.replicas(m.class)
    }

    /// The speed factor of one concrete machine (1.0 unless configured
    /// otherwise; the device pseudo-replica is always 1.0).
    pub fn speed(&self, m: MachineRef) -> f64 {
        match self.shared_index(m) {
            Some(s) => self.shared_speed(s),
            None => 1.0,
        }
    }

    /// The speed factor at a dense shared index (see
    /// [`Self::shared_index`]); allocation-free, for the simulator's hot
    /// loop.
    #[inline]
    pub fn shared_speed(&self, s: usize) -> f64 {
        self.speeds.get(s).copied().unwrap_or(1.0)
    }

    /// The cloud replicas' speed factors, materialized (length
    /// `clouds`; all 1.0 for a homogeneous class).
    pub fn cloud_speeds(&self) -> Vec<f64> {
        (0..self.clouds).map(|s| self.shared_speed(s)).collect()
    }

    /// The edge replicas' speed factors, materialized (length `edges`;
    /// all 1.0 for a homogeneous class).
    pub fn edge_speeds(&self) -> Vec<f64> {
        (self.clouds..self.shared_count())
            .map(|s| self.shared_speed(s))
            .collect()
    }

    /// The link factor of one concrete machine (1.0 unless configured
    /// otherwise; the device pseudo-replica is always 1.0 — it
    /// transmits nothing, assumption (a)).
    pub fn link(&self, m: MachineRef) -> f64 {
        match self.shared_index(m) {
            Some(s) => self.shared_link(s),
            None => 1.0,
        }
    }

    /// The link factor at a dense shared index (see
    /// [`Self::shared_index`]); allocation-free, for the simulator's hot
    /// loop.
    #[inline]
    pub fn shared_link(&self, s: usize) -> f64 {
        self.links.get(s).copied().unwrap_or(1.0)
    }

    /// The cloud replicas' link factors, materialized (length `clouds`;
    /// all 1.0 for a class on the shared network path).
    pub fn cloud_links(&self) -> Vec<f64> {
        (0..self.clouds).map(|s| self.shared_link(s)).collect()
    }

    /// The edge replicas' link factors, materialized (length `edges`;
    /// all 1.0 for a class on the shared network path).
    pub fn edge_links(&self) -> Vec<f64> {
        (self.clouds..self.shared_count())
            .map(|s| self.shared_link(s))
            .collect()
    }

    /// A job's effective processing time on a concrete machine:
    /// `ceil(p / speed)` (a faster replica finishes sooner; ceil keeps
    /// C3's non-zero integer ticks).  At speed 1.0 this is exactly `p` —
    /// the guarantee behind the homogeneous bit-for-bit invariant.
    #[inline]
    pub fn scaled_processing(&self, p: Tick, m: MachineRef) -> Tick {
        match self.shared_index(m) {
            Some(s) => scale_ticks(p, self.shared_speed(s)),
            None => p,
        }
    }

    /// A job's effective transmission time to a concrete machine:
    /// `ceil(t / link)` — the network mirror of
    /// [`Self::scaled_processing`].  At link 1.0 this is exactly `t`
    /// (the homogeneous bit-for-bit guarantee), and the device's zero
    /// transmission stays zero under any factor.
    #[inline]
    pub fn scaled_transmission(&self, t: Tick, m: MachineRef) -> Tick {
        match self.shared_index(m) {
            Some(s) => scale_ticks(t, self.shared_link(s)),
            None => t,
        }
    }

    /// All machines in canonical order: `Cloud:0..c`, `Edge:0..e`,
    /// `Device`.  This is the scheduler's move/dispatch order and the
    /// coordinator's lane order.
    pub fn machines(&self) -> Vec<MachineRef> {
        let mut v = self.shared_machines();
        v.push(MachineRef::DEVICE);
        v
    }

    /// The machine at a dense lane index (inverse of [`Self::lane_index`];
    /// allocation-free, for per-request routing).
    ///
    /// # Panics
    /// Panics if `lane >= self.lane_count()`.
    pub fn machine_at(&self, lane: usize) -> MachineRef {
        if lane < self.clouds {
            MachineRef::cloud(lane)
        } else if lane < self.shared_count() {
            MachineRef::edge(lane - self.clouds)
        } else {
            assert!(lane == self.shared_count(), "lane {lane} out of range");
            MachineRef::DEVICE
        }
    }

    /// The shared machines only (no device), canonical order.
    pub fn shared_machines(&self) -> Vec<MachineRef> {
        let mut v: Vec<MachineRef> =
            (0..self.clouds).map(MachineRef::cloud).collect();
        v.extend((0..self.edges).map(MachineRef::edge));
        v
    }

    /// Dense index of a *shared* machine into per-replica state vectors
    /// (free-times, timelines, speeds); `None` for the device.
    pub fn shared_index(&self, m: MachineRef) -> Option<usize> {
        match m.class {
            MachineId::Cloud => Some(m.replica),
            MachineId::Edge => Some(self.clouds + m.replica),
            MachineId::Device => None,
        }
    }

    /// Dense lane index (shared replicas first, device last) — the
    /// serving coordinator's queue/engine indexing.
    pub fn lane_index(&self, m: MachineRef) -> usize {
        self.shared_index(m).unwrap_or(self.shared_count())
    }

    /// The `k`-th placement within a class, cycling over its replicas —
    /// how fixed-class strategies spread load (degenerates to replica 0
    /// in the paper topology).  A class with no replicas (an edge-only
    /// ward's empty cloud tier) falls back to the device, which always
    /// exists, so fixed strategies stay total on every valid topology.
    pub fn spread(&self, class: MachineId, k: usize) -> MachineRef {
        let n = self.replicas(class);
        if n == 0 {
            return MachineRef::DEVICE;
        }
        MachineRef { class, replica: k % n }
    }

    pub fn validate(&self) -> Result<()> {
        let invalid = |reason: String| Error::InvalidTopology {
            clouds: self.clouds,
            edges: self.edges,
            reason,
        };
        if self.edges == 0 {
            return Err(invalid(
                "needs at least one edge server".into(),
            ));
        }
        if self.shared_count() > Topology::MAX_SHARED {
            return Err(invalid(format!(
                "{} shared machines; >{} is almost certainly a \
                 config typo",
                self.shared_count(),
                Topology::MAX_SHARED
            )));
        }
        for (axis, factors, range) in [
            ("speed", &self.speeds, Self::SPEED_RANGE),
            ("link", &self.links, Self::LINK_RANGE),
        ] {
            if !factors.is_empty()
                && factors.len() != self.shared_count()
            {
                return Err(invalid(format!(
                    "{} {axis} factors for {} shared machines \
                     (construct through Topology::with_factors)",
                    factors.len(),
                    self.shared_count()
                )));
            }
            for (s, &f) in factors.iter().enumerate() {
                if !f.is_finite() || !range.contains(&f) {
                    return Err(invalid(format!(
                        "{axis} factor {f} for shared machine {s} must \
                         be finite and within {range:?}"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Parse from a config section, layered over the paper defaults.
    /// Replica counts default to the speed-/link-vector lengths when
    /// only `cloud_speeds` / `edge_speeds` / `cloud_links` /
    /// `edge_links` are given.
    pub fn from_reader(r: &crate::config::FieldReader) -> Result<Self> {
        let def = Topology::paper();
        let cloud_speeds = r.f64_list("cloud_speeds")?;
        let edge_speeds = r.f64_list("edge_speeds")?;
        let cloud_links = r.f64_list("cloud_links")?;
        let edge_links = r.f64_list("edge_links")?;
        let infer = |explicit: Option<usize>,
                     speeds: &Option<Vec<f64>>,
                     links: &Option<Vec<f64>>,
                     def: usize|
         -> usize {
            explicit
                .or_else(|| speeds.as_ref().map(|v| v.len()))
                .or_else(|| links.as_ref().map(|v| v.len()))
                .unwrap_or(def)
        };
        let clouds = infer(
            r.usize("clouds")?,
            &cloud_speeds,
            &cloud_links,
            def.clouds,
        );
        let edges = infer(
            r.usize("edges")?,
            &edge_speeds,
            &edge_links,
            def.edges,
        );
        r.finish()?;
        Topology::with_factors(
            clouds,
            edges,
            cloud_speeds,
            edge_speeds,
            cloud_links,
            edge_links,
        )
    }

    /// Serialize as a config section (speed/link vectors are only
    /// emitted for heterogeneous classes, so homogeneous output is
    /// unchanged).
    pub fn to_value(&self) -> Value {
        let mut v = Value::object();
        v.set("clouds", self.clouds);
        v.set("edges", self.edges);
        let emit = |v: &mut Value, key: &str, factors: Vec<f64>| {
            // analysis: allow(float-eq, "unit factors are exact sentinels: 1.0 is stored verbatim, never computed")
            if factors.iter().any(|&f| f != 1.0) {
                v.set(key, factors);
            }
        };
        if !self.speeds.is_empty() {
            emit(&mut v, "cloud_speeds", self.cloud_speeds());
            emit(&mut v, "edge_speeds", self.edge_speeds());
        }
        if !self.links.is_empty() {
            emit(&mut v, "cloud_links", self.cloud_links());
            emit(&mut v, "edge_links", self.edge_links());
        }
        v
    }
}

/// Largest tick count the IEEE-754 division path handles exactly: up to
/// here `p as f64` is lossless, and the committed golden baselines pin
/// the `(p as f64 / factor).ceil()` result bit-for-bit (the contract
/// `python/tools/suite_oracle.py` mirrors with `math.ceil(p / f)`).
const MAX_F64_EXACT_TICK: Tick = 1 << 53;

/// `ceil(p / factor)` — the shared factor-scaling primitive behind
/// [`Topology::scaled_processing`] and [`Topology::scaled_transmission`]
/// (also the contract `python/tools/suite_oracle.py` mirrors).  The
/// `factor == 1.0` fast path is what keeps homogeneous topologies
/// bit-for-bit identical to the per-class model.
///
/// Ticks above 2^53 don't round-trip through `f64`: the old
/// float-division path silently lost precision there and the final
/// `as Tick` cast saturated.  Those are now computed by exact integer
/// ceil-division on the factor's binary mantissa/exponent decomposition
/// (every finite `f64` is `mantissa × 2^exponent` exactly), with an
/// explicit, documented saturation at `Tick::MAX` when a sub-unit
/// factor pushes the true quotient past the tick domain.  `scale_ticks
/// (p, 1.0) == p` for every `p`, and the result is monotone in `p`
/// within each regime.
#[inline]
pub fn scale_ticks(p: Tick, factor: f64) -> Tick {
    // analysis: allow(float-eq, "unit factors are exact sentinels: 1.0 is stored verbatim, never computed")
    if factor == 1.0 {
        p
    } else if p <= MAX_F64_EXACT_TICK {
        // analysis: allow(lossy-tick-cast, "p <= 2^53 so the division is exact; this is scale_ticks' audited cast")
        (p as f64 / factor).ceil() as Tick
    } else {
        scale_ticks_exact(p, factor)
    }
}

/// Exact `ceil(p / factor)` over `u128` for ticks beyond the `f64`-exact
/// range.  `factor` is a validated [`Topology::SPEED_RANGE`] /
/// [`Topology::LINK_RANGE`] value: always a positive normal `f64`, so
/// the mantissa/exponent decomposition below is total.
fn scale_ticks_exact(p: Tick, factor: f64) -> Tick {
    debug_assert!(
        factor.is_finite() && factor > 0.0 && factor.is_normal(),
        "factor {factor} outside the validated range"
    );
    // factor = mantissa * 2^exponent, exactly (IEEE-754 binary64)
    let bits = factor.to_bits();
    let mantissa = (bits & ((1u64 << 52) - 1)) | (1u64 << 52);
    let exponent = ((bits >> 52) & 0x7FF) as i32 - 1075;
    if exponent >= 0 {
        // factor >= 2^52, far outside the validated range — keep the
        // saturating float path rather than shifting out of u128
        // analysis: allow(lossy-tick-cast, "out-of-range factor fallback: documented saturation at Tick::MAX")
        return (p as f64 / factor).ceil() as Tick;
    }
    // p / factor = p * 2^(-exponent) / mantissa.  For in-range factors
    // (>= 2^-6) the exponent is in [-58, -46], so the shifted numerator
    // fits u128 comfortably (2^64 * 2^58 = 2^122).
    let shift = (-exponent) as u32;
    if shift > 63 {
        // factor below ~2^-11: the true quotient exceeds the tick
        // domain for every p in this branch (p > 2^53) — saturate
        return Tick::MAX;
    }
    let numerator = (p as u128) << shift;
    let q = numerator.div_ceil(mantissa as u128);
    Tick::try_from(q).unwrap_or(Tick::MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn machine_layer_roundtrip() {
        for m in MachineId::ALL {
            assert_eq!(MachineId::from_layer(m.layer()), m);
        }
    }

    #[test]
    fn paper_topology_machines_match_machine_id_order() {
        // the degenerate topology must enumerate exactly like the old
        // MachineId::ALL so every tie-break is preserved
        let ms = Topology::paper().machines();
        assert_eq!(
            ms,
            vec![
                MachineRef::cloud(0),
                MachineRef::edge(0),
                MachineRef::DEVICE
            ]
        );
        let classes: Vec<MachineId> = ms.iter().map(|m| m.class).collect();
        assert_eq!(classes, MachineId::ALL.to_vec());
    }

    #[test]
    fn machine_listing_and_indexing() {
        let t = Topology::new(2, 3);
        let ms = t.machines();
        assert_eq!(ms.len(), 6); // 2 + 3 + device
        assert_eq!(t.shared_count(), 5);
        assert_eq!(t.lane_count(), 6);
        for (i, &m) in t.shared_machines().iter().enumerate() {
            assert_eq!(t.shared_index(m), Some(i));
            assert_eq!(t.lane_index(m), i);
            assert!(t.contains(m));
        }
        // machine_at is the inverse of lane_index, in lane order
        for (lane, &m) in t.machines().iter().enumerate() {
            assert_eq!(t.machine_at(lane), m);
            assert_eq!(t.lane_index(t.machine_at(lane)), lane);
        }
        assert_eq!(t.shared_index(MachineRef::DEVICE), None);
        assert_eq!(t.lane_index(MachineRef::DEVICE), 5);
        assert!(!t.contains(MachineRef::cloud(2)));
        assert!(!t.contains(MachineRef::edge(3)));
        assert!(t.contains(MachineRef::DEVICE));
    }

    #[test]
    fn canonical_order_is_class_major() {
        let t = Topology::new(2, 2);
        let ms = t.machines();
        let mut sorted = ms.clone();
        sorted.sort_unstable();
        assert_eq!(ms, sorted, "machines() must already be in Ord order");
    }

    #[test]
    fn spread_cycles_replicas() {
        let t = Topology::new(1, 3);
        let picks: Vec<usize> = (0..6)
            .map(|k| t.spread(MachineId::Edge, k).replica)
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        // the paper topology degenerates to replica 0
        for k in 0..5 {
            assert_eq!(Topology::paper().spread(MachineId::Cloud, k).replica, 0);
        }
        // device is always the single pseudo-replica
        assert_eq!(t.spread(MachineId::Device, 7), MachineRef::DEVICE);
    }

    #[test]
    fn validation() {
        assert!(Topology::paper().validate().is_ok());
        // edge-only pools (a ward granted no cloud share) are valid
        assert!(Topology::new(0, 1).validate().is_ok());
        assert!(Topology::new(1, 0).validate().is_err());
        assert!(Topology::new(1, 64).validate().is_err());
        assert!(Topology::new(2, 4).validate().is_ok());
    }

    #[test]
    fn try_new_returns_typed_error() {
        assert_eq!(Topology::try_new(1, 2).unwrap(), Topology::new(1, 2));
        for (c, e) in [(1usize, 0usize), (0, 0), (32, 33)] {
            match Topology::try_new(c, e) {
                Err(Error::InvalidTopology { clouds, edges, .. }) => {
                    assert_eq!((clouds, edges), (c, e));
                }
                other => panic!("expected InvalidTopology, got {other:?}"),
            }
        }
        // the message names the offending counts
        let msg = Topology::try_new(3, 0).unwrap_err().to_string();
        assert!(msg.contains("3c+0e"), "{msg}");
    }

    #[test]
    fn cloudless_topology_is_edge_only() {
        let t = Topology::try_new(0, 2).unwrap();
        assert_eq!(t.shared_count(), 2);
        assert_eq!(
            t.machines(),
            vec![
                MachineRef::edge(0),
                MachineRef::edge(1),
                MachineRef::DEVICE
            ]
        );
        assert_eq!(t.machine_at(0), MachineRef::edge(0));
        assert_eq!(t.lane_index(MachineRef::edge(1)), 1);
        assert!(!t.contains(MachineRef::cloud(0)));
        // fixed-cloud strategies fall back to the device, which exists
        assert_eq!(t.spread(MachineId::Cloud, 3), MachineRef::DEVICE);
        assert_eq!(t.spread(MachineId::Edge, 3).replica, 1);
    }

    #[test]
    fn config_roundtrip() {
        let t = Topology::new(2, 3);
        let v = t.to_value();
        let r = crate::config::FieldReader::new(&v, "topology").unwrap();
        assert_eq!(Topology::from_reader(&r).unwrap(), t);
    }

    #[test]
    fn heterogeneous_config_roundtrip() {
        let t = Topology::heterogeneous(vec![2.0], vec![1.5, 0.75])
            .unwrap();
        let v = t.to_value();
        let r = crate::config::FieldReader::new(&v, "topology").unwrap();
        let back = Topology::from_reader(&r).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.speed(MachineRef::cloud(0)), 2.0);
        assert_eq!(back.speed(MachineRef::edge(1)), 0.75);
    }

    #[test]
    fn counts_inferred_from_speed_vectors() {
        let v = crate::serialize::toml::parse(
            "edge_speeds = [1.5, 0.75, 1.0]\n",
        )
        .unwrap();
        let r = crate::config::FieldReader::new(&v, "topology").unwrap();
        let t = Topology::from_reader(&r).unwrap();
        assert_eq!((t.clouds, t.edges), (1, 3));
        assert_eq!(t.speed(MachineRef::edge(0)), 1.5);
        // explicit mismatched count is a typed error
        let v = crate::serialize::toml::parse(
            "edges = 2\nedge_speeds = [1.5]\n",
        )
        .unwrap();
        let r = crate::config::FieldReader::new(&v, "topology").unwrap();
        assert!(matches!(
            Topology::from_reader(&r),
            Err(Error::InvalidTopology { .. })
        ));
    }

    #[test]
    fn speeds_default_to_unit_and_validate() {
        let t = Topology::new(2, 2);
        for m in t.machines() {
            assert_eq!(t.speed(m), 1.0, "{m}");
        }
        assert!(t.is_homogeneous());
        // explicit all-1.0 vectors normalize to the homogeneous form
        let explicit = Topology::with_speeds(
            2,
            2,
            Some(vec![1.0, 1.0]),
            Some(vec![1.0, 1.0]),
        )
        .unwrap();
        assert_eq!(explicit, t);
        assert!(explicit.is_homogeneous());
        // invalid factors are typed errors
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY, 1e9, 1e-9] {
            assert!(
                Topology::heterogeneous(vec![bad], vec![1.0]).is_err(),
                "{bad}"
            );
        }
        // wrong-length vectors are typed errors
        assert!(Topology::with_speeds(2, 1, Some(vec![1.5]), None)
            .is_err());
    }

    #[test]
    fn scaled_processing_ceil_and_identity() {
        let t = Topology::heterogeneous(vec![1.0], vec![2.0, 0.5])
            .unwrap();
        // unit speed: exact identity
        assert_eq!(t.scaled_processing(7, MachineRef::cloud(0)), 7);
        assert_eq!(t.scaled_processing(7, MachineRef::DEVICE), 7);
        // 2× faster: ceil(7/2) = 4
        assert_eq!(t.scaled_processing(7, MachineRef::edge(0)), 4);
        // 2× slower: 14
        assert_eq!(t.scaled_processing(7, MachineRef::edge(1)), 14);
        // C3: non-zero ticks survive scaling
        assert_eq!(t.scaled_processing(1, MachineRef::edge(0)), 1);
        assert_eq!(scale_ticks(9, 1.5), 6);
        assert_eq!(scale_ticks(10, 1.5), 7);
    }

    #[test]
    fn heterogeneous_identity_equality_and_hash() {
        use std::collections::HashSet;
        let a = Topology::heterogeneous(vec![1.0], vec![1.5]).unwrap();
        let b = Topology::heterogeneous(vec![1.0], vec![1.5]).unwrap();
        let c = Topology::new(1, 1);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(!a.is_paper());
        assert!(c.is_paper());
        let set: HashSet<Topology> =
            [a.clone(), b, c].into_iter().collect();
        assert_eq!(set.len(), 2);
        assert!(a.label().contains("speeds=[1,1.5]"), "{}", a.label());
        assert_eq!(Topology::new(1, 2).label(), "1c+2e");
    }

    #[test]
    fn links_default_to_unit_and_validate() {
        let t = Topology::new(2, 2);
        for m in t.machines() {
            assert_eq!(t.link(m), 1.0, "{m}");
        }
        assert!(t.is_homogeneous());
        // explicit all-1.0 link vectors normalize to the homogeneous form
        let explicit = Topology::with_links(
            2,
            2,
            Some(vec![1.0, 1.0]),
            Some(vec![1.0, 1.0]),
        )
        .unwrap();
        assert_eq!(explicit, t);
        assert!(explicit.is_homogeneous());
        // invalid factors are typed errors
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY, 1e9, 1e-9] {
            assert!(
                Topology::with_links(1, 1, Some(vec![bad]), None)
                    .is_err(),
                "{bad}"
            );
        }
        // wrong-length vectors are typed errors naming the field
        let err = Topology::with_links(2, 1, Some(vec![1.5]), None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("cloud_links"), "{err}");
    }

    #[test]
    fn scaled_transmission_ceil_and_identity() {
        let t = Topology::with_links(
            1,
            2,
            Some(vec![1.0]),
            Some(vec![2.0, 0.5]),
        )
        .unwrap();
        // unit link: exact identity
        assert_eq!(t.scaled_transmission(7, MachineRef::cloud(0)), 7);
        assert_eq!(t.scaled_transmission(7, MachineRef::DEVICE), 7);
        // 2x link: ceil(7/2) = 4; half-rate Wi-Fi: 14
        assert_eq!(t.scaled_transmission(7, MachineRef::edge(0)), 4);
        assert_eq!(t.scaled_transmission(7, MachineRef::edge(1)), 14);
        // zero transmission (the device's) stays zero under any factor
        assert_eq!(t.scaled_transmission(0, MachineRef::edge(1)), 0);
        // C3: non-zero ticks survive scaling
        assert_eq!(t.scaled_transmission(1, MachineRef::edge(0)), 1);
        // processing is untouched by link factors
        assert_eq!(t.scaled_processing(7, MachineRef::edge(0)), 7);
    }

    #[test]
    fn link_config_roundtrip_and_count_inference() {
        let t = Topology::with_factors(
            2,
            1,
            Some(vec![2.0, 1.0]),
            None,
            Some(vec![0.5, 1.0]),
            Some(vec![1.5]),
        )
        .unwrap();
        let v = t.to_value();
        let r = crate::config::FieldReader::new(&v, "topology").unwrap();
        let back = Topology::from_reader(&r).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.link(MachineRef::cloud(0)), 0.5);
        assert_eq!(back.link(MachineRef::edge(0)), 1.5);
        assert_eq!(back.speed(MachineRef::cloud(0)), 2.0);
        // counts are inferrable from link vectors alone
        let v = crate::serialize::toml::parse(
            "edge_links = [0.5, 1.0, 2.0]\n",
        )
        .unwrap();
        let r = crate::config::FieldReader::new(&v, "topology").unwrap();
        let t = Topology::from_reader(&r).unwrap();
        assert_eq!((t.clouds, t.edges), (1, 3));
        assert_eq!(t.link(MachineRef::edge(0)), 0.5);
        assert!(t.speed(MachineRef::edge(0)) == 1.0);
        // explicit mismatched count is a typed error
        let v = crate::serialize::toml::parse(
            "edges = 2\nedge_links = [1.5]\n",
        )
        .unwrap();
        let r = crate::config::FieldReader::new(&v, "topology").unwrap();
        assert!(matches!(
            Topology::from_reader(&r),
            Err(Error::InvalidTopology { .. })
        ));
    }

    #[test]
    fn link_identity_equality_hash_and_label() {
        use std::collections::HashSet;
        let a = Topology::with_links(1, 1, None, Some(vec![0.5]))
            .unwrap();
        let b = Topology::with_links(1, 1, None, Some(vec![0.5]))
            .unwrap();
        let speeds_only =
            Topology::heterogeneous(vec![1.0], vec![0.5]).unwrap();
        let unit = Topology::new(1, 1);
        assert_eq!(a, b);
        assert_ne!(a, speeds_only, "links are not speeds");
        assert_ne!(a, unit);
        assert!(!a.is_paper() && !a.is_homogeneous());
        let set: HashSet<Topology> =
            [a.clone(), b, speeds_only, unit].into_iter().collect();
        assert_eq!(set.len(), 3);
        assert!(a.label().contains("links=[1,0.5]"), "{}", a.label());
        let both = Topology::with_factors(
            1,
            1,
            None,
            Some(vec![2.0]),
            None,
            Some(vec![0.5]),
        )
        .unwrap();
        let l = both.label();
        assert!(
            l.contains("speeds=[1,2]") && l.contains("links=[1,0.5]"),
            "{l}"
        );
    }

    #[test]
    fn scale_ticks_exact_beyond_f64_range() {
        // the documented bugfix: (2^60 + 1) / 2 lost the +1 through f64
        let p = (1u64 << 60) + 1;
        assert_eq!(scale_ticks(p, 2.0), (1 << 59) + 1);
        assert_eq!(scale_ticks(p, 1.0), p, "unit factor is the identity");
        assert_eq!(scale_ticks(u64::MAX, 1.0), u64::MAX);
        // exact agreement with integer arithmetic on a power-of-two
        // factor, where both paths are exact
        assert_eq!(scale_ticks(1 << 54, 2.0), 1 << 53);
        assert_eq!(scale_ticks((1 << 54) + 3, 4.0), (1 << 52) + 1);
        // sub-unit factors past the tick domain saturate explicitly
        assert_eq!(scale_ticks(u64::MAX, 0.5), u64::MAX);
        assert_eq!(scale_ticks(u64::MAX - 7, 0.015625), u64::MAX);
        // speeding never lengthens, slowing never shortens
        assert!(scale_ticks(p, 4.0) <= scale_ticks(p, 2.0));
        assert!(scale_ticks(p, 0.5) >= p);
    }

    #[test]
    fn scale_ticks_large_tick_identity_and_monotonicity() {
        // property pinned by the ISSUE: identity at 1.0 for huge ticks,
        // and monotone in p within the exact-integer regime
        let mut rng = crate::data::Rng::new(0x71C5);
        for _ in 0..500 {
            let p = (1u64 << 53) + 1 + rng.below(1 << 62);
            assert_eq!(scale_ticks(p, 1.0), p);
            for factor in [0.75, 1.5, 2.0, 3.0, 64.0, 0.015625] {
                let a = scale_ticks(p, factor);
                let b = scale_ticks(p + 1, factor);
                assert!(
                    a <= b,
                    "scale_ticks not monotone at p={p} factor={factor}: \
                     {a} > {b}"
                );
                // ceil-division bounds: q >= p/f - 1 and q <= p/f + 1
                // checked exactly via the inverse on non-saturated results
                if a < u64::MAX && factor >= 1.0 {
                    assert!(a <= p, "speed-up lengthened {p} -> {a}");
                }
            }
        }
    }

    #[test]
    fn display_keeps_paper_labels() {
        assert_eq!(MachineRef::cloud(0).to_string(), "Cloud");
        assert_eq!(MachineRef::edge(1).to_string(), "Edge:1");
        assert_eq!(MachineRef::DEVICE.to_string(), "Device");
        assert_eq!(MachineRef::edge(1).label(), "ES1");
        assert_eq!(MachineRef::DEVICE.label(), "ED");
    }
}
