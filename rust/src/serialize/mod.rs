//! In-tree serialization substrate.
//!
//! This environment builds fully offline against a fixed vendored crate
//! set that does not include serde/serde_json/toml, so the two interchange
//! formats the framework needs are implemented here from scratch
//! (substitution ledger, DESIGN.md §3):
//!
//! * [`json`] — a complete JSON value model, parser and writer.  Used for
//!   `artifacts/manifest.json` (the contract with the python AOT path) and
//!   for `--json` report output.
//! * [`toml`] — the TOML subset the config system uses: dotted/nested
//!   sections, scalars, homogeneous scalar arrays, comments.
//!
//! Both parsers are tested against adversarial inputs and round-trip the
//! framework's own documents bit-exactly.

pub mod json;
pub mod toml;

pub use json::Value;
