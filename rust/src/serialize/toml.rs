//! TOML-subset parser for the config system.
//!
//! Supported grammar (everything `Config` and the presets use):
//!
//! * `# comments` and blank lines
//! * `[section]` and dotted `[section.sub]` headers
//! * `[[section.item]]` array-of-tables headers (each occurrence appends
//!   one table; later `[section.item.sub]` headers and dotted keys
//!   address the *last* appended table, per the TOML spec) — the
//!   `[[metro.ward]]` layout
//! * `key = value` with dotted keys
//! * values: basic strings (`"..."` with the JSON escape set), integers,
//!   floats (incl. `inf`/`nan` forms TOML allows), booleans, homogeneous
//!   arrays of scalars, and inline tables `{ k = v, ... }`
//!
//! Documents parse into the shared [`Value`] model (objects/arrays/
//! scalars), so config extraction code is format-agnostic.

use super::json::Value;
use crate::{Error, Result};

/// Parse TOML text into a [`Value::Object`].
pub fn parse(text: &str) -> Result<Value> {
    let mut root = Value::object();
    let mut section_path: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            if let Some(inner) = rest.strip_prefix('[') {
                // [[array.of.tables]]: append one table, address it
                let inner = inner.strip_suffix("]]").ok_or_else(|| {
                    err(lineno, "unterminated array-of-tables header")
                })?;
                section_path = parse_dotted_key(inner, lineno)?;
                push_array_table(&mut root, &section_path, lineno)?;
            } else {
                let inner = rest.strip_suffix(']').ok_or_else(|| {
                    err(lineno, "unterminated section header")
                })?;
                section_path = parse_dotted_key(inner, lineno)?;
                // ensure the section object exists
                ensure_path(&mut root, &section_path, lineno)?;
            }
        } else {
            let eq = find_unquoted_eq(line)
                .ok_or_else(|| err(lineno, "expected key = value"))?;
            let (k, v) = line.split_at(eq);
            let v = &v[1..];
            let mut path = section_path.clone();
            path.extend(parse_dotted_key(k.trim(), lineno)?);
            let value = parse_value(v.trim(), lineno)?;
            insert_path(&mut root, &path, value, lineno)?;
        }
    }
    Ok(root)
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Toml(format!("line {}: {msg}", lineno + 1))
}

/// Strip a trailing comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
        escaped = in_str && c == '\\' && !escaped;
    }
    line
}

fn find_unquoted_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn parse_dotted_key(s: &str, lineno: usize) -> Result<Vec<String>> {
    let parts: Vec<String> = s
        .split('.')
        .map(|p| p.trim().trim_matches('"').to_string())
        .collect();
    if parts.iter().any(|p| p.is_empty()) {
        return Err(err(lineno, "empty key segment"));
    }
    Ok(parts)
}

fn ensure_path<'a>(
    root: &'a mut Value,
    path: &[String],
    lineno: usize,
) -> Result<&'a mut Value> {
    let mut cur = root;
    for seg in path {
        // a path segment landing on an array-of-tables addresses the
        // most recently appended table (TOML's [[...]] semantics)
        if let Value::Array(items) = cur {
            cur = items.last_mut().ok_or_else(|| {
                err(lineno, "key path crosses an empty array")
            })?;
        }
        let Value::Object(entries) = cur else {
            return Err(err(lineno, "key path crosses a non-table"));
        };
        let idx = match entries.iter().position(|(k, _)| k == seg) {
            Some(i) => i,
            None => {
                entries.push((seg.clone(), Value::object()));
                entries.len() - 1
            }
        };
        cur = &mut entries[idx].1;
    }
    if let Value::Array(items) = cur {
        cur = items.last_mut().ok_or_else(|| {
            err(lineno, "key path crosses an empty array")
        })?;
    }
    Ok(cur)
}

/// Append one table to the array at `path` (creating the array on first
/// use), per a `[[path]]` header.
fn push_array_table(
    root: &mut Value,
    path: &[String],
    lineno: usize,
) -> Result<()> {
    let (last, parents) = path
        .split_last()
        .ok_or_else(|| err(lineno, "empty array-of-tables header"))?;
    let parent = ensure_path(root, parents, lineno)?;
    let Value::Object(entries) = parent else {
        return Err(err(lineno, "parent is not a table"));
    };
    match entries.iter_mut().find(|(k, _)| k == last) {
        None => entries
            .push((last.clone(), Value::Array(vec![Value::object()]))),
        Some((_, Value::Array(items))) => items.push(Value::object()),
        Some(_) => {
            return Err(err(
                lineno,
                &format!("{last:?} is already a non-array value"),
            ))
        }
    }
    Ok(())
}

fn insert_path(
    root: &mut Value,
    path: &[String],
    value: Value,
    lineno: usize,
) -> Result<()> {
    // analysis: allow(bare-unwrap, "parse_key never yields an empty path: every key line has at least one segment")
    let (last, parents) = path.split_last().expect("non-empty path");
    let parent = ensure_path(root, parents, lineno)?;
    let Value::Object(entries) = parent else {
        return Err(err(lineno, "parent is not a table"));
    };
    if entries
        .iter()
        .any(|(k, v)| k == last && !matches!(v, Value::Object(o) if o.is_empty()))
    {
        return Err(err(lineno, &format!("duplicate key {last:?}")));
    }
    if let Some(e) = entries.iter_mut().find(|(k, _)| k == last) {
        e.1 = value;
    } else {
        entries.push((last.clone(), value));
    }
    Ok(())
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if s.is_empty() {
        return Err(err(lineno, "empty value"));
    }
    // string
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(lineno, "unterminated string"))?;
        return unescape(inner, lineno);
    }
    // array
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(err(lineno, "unterminated array (must be single-line)"));
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let p = part.trim();
            if p.is_empty() {
                continue;
            }
            items.push(parse_value(p, lineno)?);
        }
        return Ok(Value::Array(items));
    }
    // inline table
    if s.starts_with('{') {
        if !s.ends_with('}') {
            return Err(err(lineno, "unterminated inline table"));
        }
        let inner = &s[1..s.len() - 1];
        let mut obj = Value::object();
        for part in split_top_level(inner) {
            let p = part.trim();
            if p.is_empty() {
                continue;
            }
            let eq = find_unquoted_eq(p)
                .ok_or_else(|| err(lineno, "inline table needs k = v"))?;
            let (k, v) = p.split_at(eq);
            obj.set(
                k.trim().trim_matches('"'),
                parse_value(v[1..].trim(), lineno)?,
            );
        }
        return Ok(obj);
    }
    // booleans
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    // numbers (TOML allows underscores, inf, nan)
    let cleaned = s.replace('_', "");
    match cleaned.as_str() {
        "inf" | "+inf" => return Ok(Value::Number(f64::INFINITY)),
        "-inf" => return Ok(Value::Number(f64::NEG_INFINITY)),
        "nan" | "+nan" | "-nan" => return Ok(Value::Number(f64::NAN)),
        _ => {}
    }
    cleaned
        .parse::<f64>()
        .map(Value::Number)
        .map_err(|_| err(lineno, &format!("cannot parse value {s:?}")))
}

/// Split on top-level commas (not inside strings/brackets).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0i32;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' | '{' if !in_str => depth += 1,
            ']' | '}' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

fn unescape(s: &str, lineno: usize) -> Result<Value> {
    let mut out = String::new();
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            Some('u') => {
                let hex: String = chars.by_ref().take(4).collect();
                let cp = u32::from_str_radix(&hex, 16)
                    .map_err(|_| err(lineno, "bad \\u escape"))?;
                out.push(
                    char::from_u32(cp)
                        .ok_or_else(|| err(lineno, "bad codepoint"))?,
                );
            }
            _ => return Err(err(lineno, "unknown escape")),
        }
    }
    Ok(Value::String(out))
}

/// Whether a value must serialize as `[[path]]` headers (a non-empty
/// array whose elements are all tables).
fn is_table_array(v: &Value) -> bool {
    matches!(v, Value::Array(items)
        if !items.is_empty()
            && items.iter().all(|i| matches!(i, Value::Object(_))))
}

/// Whether a value serializes as its own section(s) rather than inline.
fn is_sectional(v: &Value) -> bool {
    matches!(v, Value::Object(_)) || is_table_array(v)
}

/// Serialize a [`Value::Object`] as TOML (sections for nested objects,
/// `[[...]]` headers for arrays of tables, inline values otherwise).
/// The inverse of [`parse`] for the documents the config system emits.
pub fn emit(v: &Value) -> String {
    let mut out = String::new();
    let Value::Object(entries) = v else {
        return out;
    };
    // scalars first, then sections
    for (k, val) in entries {
        if !is_sectional(val) {
            out.push_str(&format!("{k} = {}\n", emit_value(val)));
        }
    }
    for (k, val) in entries {
        if is_sectional(val) {
            emit_section(&mut out, k, val);
        }
    }
    out
}

fn emit_section(out: &mut String, path: &str, v: &Value) {
    if let Value::Array(items) = v {
        // array-of-tables: one [[path]] header per element; each
        // element's own scalars and subsections follow it, so the
        // parser's "address the last table" rule reassembles exactly
        for item in items {
            let Value::Object(entries) = item else { continue };
            out.push_str(&format!("\n[[{path}]]\n"));
            for (k, val) in entries {
                if !is_sectional(val) {
                    out.push_str(&format!(
                        "{k} = {}\n",
                        emit_value(val)
                    ));
                }
            }
            for (k, val) in entries {
                if is_sectional(val) {
                    emit_section(out, &format!("{path}.{k}"), val);
                }
            }
        }
        return;
    }
    let Value::Object(entries) = v else { return };
    let scalars: Vec<_> = entries
        .iter()
        .filter(|(_, v)| !is_sectional(v))
        .collect();
    if !scalars.is_empty() || entries.is_empty() {
        out.push_str(&format!("\n[{path}]\n"));
        for (k, val) in &scalars {
            out.push_str(&format!("{k} = {}\n", emit_value(val)));
        }
    }
    for (k, val) in entries {
        if is_sectional(val) {
            emit_section(out, &format!("{path}.{k}"), val);
        }
    }
}

fn emit_value(v: &Value) -> String {
    match v {
        Value::Null => "\"\"".into(),
        Value::Bool(b) => b.to_string(),
        Value::Number(n) => {
            // analysis: allow(float-eq, "fract() == 0.0 is an exact integrality test, not a tolerance comparison")
            if n.fract() == 0.0 && n.is_finite() && n.abs() < 9.0e15 {
                // keep floats recognizable as floats for round-trip clarity
                format!("{:.1}", n)
                    .trim_end_matches(".0")
                    .to_string()
                    + if *n as i64 as f64 == *n { "" } else { "" }
            } else if n.is_infinite() {
                if *n > 0.0 { "inf".into() } else { "-inf".into() }
            } else {
                format!("{n}")
            }
        }
        Value::String(s) => Value::String(s.clone()).to_string(),
        Value::Array(items) => {
            let inner: Vec<String> = items.iter().map(emit_value).collect();
            format!("[{}]", inner.join(", "))
        }
        Value::Object(_) => "{}".into(), // nested objects become sections
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_document() {
        let doc = r#"
# top comment
seed = 42
name = "paper"            # trailing comment
ratio = 0.5

[serve]
patients = 4
mix = [0.4, 0.4, 0.2]
emulate = true

[environment.cloud]
cores = 12
freq_ghz = 2.2
"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("seed").unwrap().as_u64(), Some(42));
        assert_eq!(v.get("name").unwrap().as_str(), Some("paper"));
        assert_eq!(
            v.get("serve").unwrap().get("patients").unwrap().as_u64(),
            Some(4)
        );
        assert_eq!(
            v.get("serve").unwrap().get("mix").unwrap().as_array().unwrap().len(),
            3
        );
        assert_eq!(
            v.get("environment")
                .unwrap()
                .get("cloud")
                .unwrap()
                .get("cores")
                .unwrap()
                .as_u64(),
            Some(12)
        );
    }

    #[test]
    fn inline_table() {
        let v = parse("link = { latency_ms = 42.0, bandwidth_mbs = 2.9 }")
            .unwrap();
        let link = v.get("link").unwrap();
        assert_eq!(link.get("latency_ms").unwrap().as_f64(), Some(42.0));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let v = parse("s = \"a#b\"").unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn bad_lines_rejected() {
        for bad in ["[sec", "= 3", "x =", "x = [1, ", "x = \"abc"] {
            assert!(parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn underscored_numbers_and_inf() {
        let v = parse("big = 1_000_000\nx = inf\ny = -inf").unwrap();
        assert_eq!(v.get("big").unwrap().as_u64(), Some(1_000_000));
        assert_eq!(v.get("x").unwrap().as_f64(), Some(f64::INFINITY));
    }

    #[test]
    fn emit_roundtrip() {
        let doc = "seed = 7\n\n[serve]\npatients = 3\nmix = [0.5, 0.5, 0]\n";
        let v = parse(doc).unwrap();
        let emitted = emit(&v);
        let back = parse(&emitted).unwrap();
        assert_eq!(back, v, "emitted:\n{emitted}");
    }

    #[test]
    fn array_of_tables() {
        let doc = r#"
[metro]
name = "tri"

[[metro.ward]]
name = "icu-a"
edges = 2

[metro.ward.scheduler]
tenure = 7

[[metro.ward]]
name = "icu-b"
edges = 1
"#;
        let v = parse(doc).unwrap();
        let wards = v
            .get("metro")
            .unwrap()
            .get("ward")
            .unwrap()
            .as_array()
            .unwrap();
        assert_eq!(wards.len(), 2);
        assert_eq!(wards[0].get("name").unwrap().as_str(), Some("icu-a"));
        assert_eq!(wards[0].get("edges").unwrap().as_u64(), Some(2));
        // the dotted subsection landed on the *first* ward (it was the
        // last appended table at that point)
        assert_eq!(
            wards[0]
                .get("scheduler")
                .unwrap()
                .get("tenure")
                .unwrap()
                .as_u64(),
            Some(7)
        );
        assert_eq!(wards[1].get("name").unwrap().as_str(), Some("icu-b"));
        assert!(wards[1].get("scheduler").is_none());
    }

    #[test]
    fn array_of_tables_emit_roundtrip() {
        let doc = "\
[metro]\nseed = 7\n\n[[metro.ward]]\nname = \"a\"\nedges = 2\n\n\
[[metro.ward]]\nname = \"b\"\nrate = 0.5\n";
        let v = parse(doc).unwrap();
        let emitted = emit(&v);
        let back = parse(&emitted).unwrap();
        assert_eq!(back, v, "emitted:\n{emitted}");
        assert!(emitted.contains("[[metro.ward]]"), "{emitted}");
    }

    #[test]
    fn array_of_tables_bad_headers_rejected() {
        assert!(parse("[[sec]").is_err());
        assert!(parse("x = 1\n[[x]]\n").is_err());
    }

    #[test]
    fn dotted_keys() {
        let v = parse("a.b.c = 1").unwrap();
        assert_eq!(
            v.get("a").unwrap().get("b").unwrap().get("c").unwrap().as_u64(),
            Some(1)
        );
    }
}
