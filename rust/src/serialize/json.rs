//! Minimal-but-complete JSON: value model, recursive-descent parser,
//! compact + pretty writers.
//!
//! Supports the full JSON grammar (RFC 8259): all escapes including
//! `\uXXXX` (with surrogate pairs), exponent-form numbers, arbitrarily
//! nested containers.  Objects preserve insertion order so documents the
//! framework writes round-trip stably.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// All numbers are f64 (integers up to 2^53 round-trip exactly, far
    /// beyond anything in our documents).
    Number(f64),
    String(String),
    Array(Vec<Value>),
    /// Insertion-ordered object.
    Object(Vec<(String, Value)>),
}

impl Value {
    // ------------------------------------------------------ constructors
    pub fn object() -> Value {
        Value::Object(Vec::new())
    }

    /// Insert/overwrite a key in an object (panics on non-objects:
    /// builder-style use only).
    pub fn set(&mut self, key: &str, v: impl Into<Value>) -> &mut Self {
        let Value::Object(entries) = self else {
            panic!("Value::set on non-object")
        };
        if let Some(e) = entries.iter_mut().find(|(k, _)| k == key) {
            e.1 = v.into();
        } else {
            entries.push((key.to_string(), v.into()));
        }
        self
    }

    // ------------------------------------------------------- accessors
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(entries) => {
                entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Value> {
        match self {
            Value::Array(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            // analysis: allow(float-eq, "fract() == 0.0 is an exact integrality test, not a tolerance comparison")
            Value::Number(n) if *n >= 0.0 && n.fract() == 0.0 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Object(v) => Some(v),
            _ => None,
        }
    }

    /// Typed field access with path-aware errors.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing field {key:?}")))
    }

    /// Recursively sort object keys (ascending byte order) — the
    /// canonical form for documents that must serialize byte-identically
    /// across runs (suite results, golden baselines).
    pub fn sort_keys(&mut self) {
        match self {
            Value::Array(items) => {
                items.iter_mut().for_each(Value::sort_keys)
            }
            Value::Object(entries) => {
                entries.iter_mut().for_each(|(_, v)| v.sort_keys());
                entries.sort_by(|a, b| a.0.cmp(&b.0));
            }
            _ => {}
        }
    }

    // --------------------------------------------------------- writers
    /// Compact rendering.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Number(n) => write_number(out, *n),
            Value::String(s) => write_string(out, s),
            Value::Array(items) => {
                write_container(out, indent, depth, '[', ']', items.len(), |out, i| {
                    items[i].write(out, indent, depth + 1);
                })
            }
            Value::Object(entries) => {
                write_container(out, indent, depth, '{', '}', entries.len(), |out, i| {
                    write_string(out, &entries[i].0);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    entries[i].1.write(out, indent, depth + 1);
                })
            }
        }
    }
}

fn write_container(
    out: &mut String,
    indent: Option<usize>,
    depth: usize,
    open: char,
    close: char,
    n: usize,
    mut write_item: impl FnMut(&mut String, usize),
) {
    out.push(open);
    if n == 0 {
        out.push(close);
        return;
    }
    for i in 0..n {
        if i > 0 {
            out.push(',');
        }
        if let Some(w) = indent {
            out.push('\n');
            out.push_str(&" ".repeat(w * (depth + 1)));
        }
        write_item(out, i);
    }
    if let Some(w) = indent {
        out.push('\n');
        out.push_str(&" ".repeat(w * depth));
    }
    out.push(close);
}

fn write_number(out: &mut String, n: f64) {
    // analysis: allow(float-eq, "fract() == 0.0 is an exact integrality test, not a tolerance comparison")
    if n.fract() == 0.0 && n.abs() < 9.0e15 {
        let _ = write!(out, "{}", n as i64);
    } else if n.is_finite() {
        let _ = write!(out, "{n}");
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------- From
impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Value {
        Value::Number(n)
    }
}
impl From<u64> for Value {
    fn from(n: u64) -> Value {
        Value::Number(n as f64)
    }
}
impl From<u32> for Value {
    fn from(n: u32) -> Value {
        Value::Number(n as f64)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Value {
        Value::Number(n as f64)
    }
}
impl From<i64> for Value {
    fn from(n: i64) -> Value {
        Value::Number(n as f64)
    }
}
impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::String(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Value {
        Value::String(s)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Value {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}
impl From<BTreeMap<String, Value>> for Value {
    fn from(m: BTreeMap<String, Value>) -> Value {
        Value::Object(m.into_iter().collect())
    }
}

// -------------------------------------------------------------- parser
/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(entries));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            entries.push((key, v));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(entries)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair?
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk =
                            std::str::from_utf8(&self.bytes[start..end])
                                .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number bytes"))?;
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Number(-150.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::String("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            v.get("a").unwrap().idx(1).unwrap().get("b"),
            Some(&Value::Null)
        );
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn escapes_roundtrip() {
        let s = "tab\t nl\n quote\" back\\ unicode\u{263a} ctrl\u{1}";
        let v = Value::String(s.into());
        let parsed = parse(&v.to_string()).unwrap();
        assert_eq!(parsed.as_str(), Some(s));
    }

    #[test]
    fn surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"\\x\"",
                    "{\"a\":}", "[1]]", "nan"] {
            assert!(parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn pretty_and_compact_agree() {
        let mut v = Value::object();
        v.set("x", 1u64).set("y", vec!["a", "b"]).set("z", true);
        let compact = parse(&v.to_string()).unwrap();
        let pretty = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(compact, pretty);
        assert_eq!(compact, v);
    }

    #[test]
    fn integers_exact() {
        let v = parse("9007199254740991").unwrap(); // 2^53 - 1
        assert_eq!(v.as_u64(), Some(9007199254740991));
        assert_eq!(v.to_string(), "9007199254740991");
    }

    #[test]
    fn object_insertion_order_preserved() {
        let v = parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"z":1,"a":2}"#);
    }

    #[test]
    fn sort_keys_is_canonical_and_recursive() {
        let mut v =
            parse(r#"{"z":1,"a":{"y":[{"b":2,"a":3}],"x":0}}"#).unwrap();
        v.sort_keys();
        assert_eq!(
            v.to_string(),
            r#"{"a":{"x":0,"y":[{"a":3,"b":2}]},"z":1}"#
        );
        // idempotent, and equal to sorting any insertion order
        let mut w =
            parse(r#"{"a":{"x":0,"y":[{"a":3,"b":2}]},"z":1}"#).unwrap();
        w.sort_keys();
        assert_eq!(v, w);
        v.sort_keys();
        assert_eq!(v, w);
    }

    #[test]
    fn set_overwrites() {
        let mut v = Value::object();
        v.set("k", 1u64);
        v.set("k", 2u64);
        assert_eq!(v.get("k").unwrap().as_u64(), Some(2));
        assert_eq!(v.as_object().unwrap().len(), 1);
    }

    #[test]
    fn req_errors_name_field() {
        let v = parse("{}").unwrap();
        let err = v.req("seed").unwrap_err();
        assert!(err.to_string().contains("seed"));
    }

    #[test]
    fn deep_nesting() {
        let doc = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        let v = parse(&doc).unwrap();
        let mut cur = &v;
        for _ in 0..100 {
            cur = cur.idx(0).unwrap();
        }
        assert_eq!(cur.as_f64(), Some(1.0));
    }
}
