//! In-tree static analysis: the determinism & concurrency lint pass
//! behind `edgeward analyze` (the module README).
//!
//! Every result this crate ships — Table VII cells, suite goldens,
//! metro reports, `BENCH_serve.json` — is gated on byte-exact
//! determinism, and the hot paths run on scoped thread pools, atomics,
//! and a timing wheel.  One unordered-map iteration feeding an emitter
//! or one mis-ordered atomic silently breaks the guarantee the whole
//! golden corpus rests on.  This pass mechanically enforces the
//! contract; see the crate docs ("Determinism contract") for the rule
//! rationale.  The rules:
//!
//! * `unordered-emit` — `HashMap`/`HashSet` in report-emitting modules
//!   (`benchkit/`, `loadtest/`, `metrics/`, `metro/`, `report/`,
//!   `serialize/`, `suite/`): iteration order would leak
//!   nondeterminism into emitted bytes.
//! * `wall-clock-in-pure` — `Instant::now` / `SystemTime` outside the
//!   real-time allowlist (`coordinator/delay.rs`, `main.rs`,
//!   `runtime/`, `benchkit/`): wall-clock reads make pure-path results
//!   machine-dependent.
//! * `float-eq` — `==` / `!=` against a float literal: only documented
//!   exact sentinels (unit factors, `fract() == 0.0`) may compare
//!   floats exactly, and each such site carries a justification.
//! * `lossy-tick-cast` — ad-hoc `as Tick` casts, or `ceil()/round()/
//!   floor()/as_nanos()`-style results cast to a narrow integer, in
//!   tick-handling modules: `topology::scale_ticks` is the blessed
//!   conversion; anything else documents its bound.
//! * `relaxed-sync` — `Ordering::Relaxed` outside the allocation
//!   counter: each use states its happens-before edge or why none is
//!   needed.
//! * `unscoped-spawn` — `thread::spawn` / `thread::Builder` outside
//!   `runtime/`: prefer `std::thread::scope`; long-lived serving
//!   threads justify their join point.
//! * `bare-unwrap` — `.unwrap()` / `.expect("…")` in library (non-test,
//!   non-`main.rs`) code: return a typed [`Error`] where a caller can
//!   hit it, or justify the locally-provable invariant.
//! * `unjustified-allow` — the meta-rule: a suppression comment that is
//!   malformed, names an unknown rule, or omits its justification is
//!   itself a finding.  Suppressions can never be suppressed.
//!
//! ## Suppressing a finding
//!
//! Add a line comment on the flagged line or the line above:
//!
//! ```text
//! // analysis: allow(bare-unwrap, "guard held; non-empty by the check above")
//! ```
//!
//! The justification string is mandatory — the pass exists to make
//! every exception reviewable, not to provide an escape hatch.
//!
//! ## Independent mirror
//!
//! `python/tools/analyze_mirror.py` reimplements the lexer, the rules,
//! and the suppression grammar from scratch (the `suite_oracle.py`
//! idiom) and runs in CI without a Cargo toolchain; both
//! implementations must report a clean tree.

pub mod lex;
pub mod rules;

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use crate::error::{Error, Result};
use crate::serialize::json::Value;

pub use rules::{Finding, RULES};

/// The suppression-comment marker: `// analysis: allow(<rule>, "<why>")`.
const MARKER: &str = "analysis:";

/// Resolve `--rules` (comma-separated, `None` = all) into the active
/// set, rejecting unknown names.
pub fn active_rules(csv: Option<&str>) -> Result<BTreeSet<String>> {
    let Some(csv) = csv else {
        return Ok(RULES.iter().map(|r| r.to_string()).collect());
    };
    let mut active = BTreeSet::new();
    for name in csv.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        if !RULES.contains(&name) {
            return Err(Error::Analysis(format!(
                "unknown rule {name:?} (known: {})",
                RULES.join(", ")
            )));
        }
        active.insert(name.to_string());
    }
    if active.is_empty() {
        return Err(Error::Analysis("--rules names no rules".into()));
    }
    Ok(active)
}

/// The deterministic result of one pass: findings sorted by
/// (file, line, rule), plus the suppression count and the active rule
/// set.
pub struct Report {
    pub findings: Vec<Finding>,
    pub suppressed: usize,
    pub rules: Vec<String>,
    pub root: String,
}

impl Report {
    /// No findings — the tree passes `--check`.
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// The human-readable report (one line per finding + a summary
    /// footer), identical in shape to the Python mirror's output.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let _ = writeln!(
                out,
                "{:<18} {}:{}  {}",
                f.rule, f.file, f.line, f.message
            );
        }
        let _ = writeln!(
            out,
            "{} finding(s), {} suppressed, {} rule(s) active",
            self.findings.len(),
            self.suppressed,
            self.rules.len()
        );
        out
    }

    /// The `--json` document (sorted keys, stable across runs).
    pub fn to_value(&self) -> Value {
        let mut counts: BTreeMap<&str, u64> = BTreeMap::new();
        for f in &self.findings {
            *counts.entry(f.rule).or_insert(0) += 1;
        }
        let mut counts_v = Value::object();
        for (rule, n) in counts {
            counts_v.set(rule, n);
        }
        let findings: Vec<Value> = self
            .findings
            .iter()
            .map(|f| {
                let mut o = Value::object();
                o.set("file", f.file.as_str());
                o.set("line", f.line);
                o.set("message", f.message.as_str());
                o.set("rule", f.rule);
                o
            })
            .collect();
        let mut doc = Value::object();
        doc.set("counts", counts_v);
        doc.set("findings", Value::Array(findings));
        doc.set("root", self.root.as_str());
        doc.set(
            "rules",
            Value::Array(
                self.rules.iter().map(|r| Value::String(r.clone())).collect(),
            ),
        );
        doc.set("suppressed", self.suppressed as u64);
        doc
    }
}

/// Extract `allow()` suppressions from a file's comments; malformed
/// ones become `unjustified-allow` findings.  A valid allow suppresses
/// rule R on its own line and the next line (covering both the
/// trailing-comment and the comment-above styles).
fn parse_suppressions(
    comments: &[lex::Comment],
    path: &str,
    findings: &mut Vec<Finding>,
) -> BTreeSet<(&'static str, u32)> {
    let mut allowed = BTreeSet::new();
    for c in comments {
        let t = c.text.trim();
        let Some(body) = t.strip_prefix(MARKER) else {
            continue;
        };
        let body = body.trim();
        let mut ok = false;
        if let Some(inner) =
            body.strip_prefix("allow(").and_then(|b| b.strip_suffix(')'))
        {
            let (rule_txt, just) = match inner.find(',') {
                Some(comma) => {
                    (inner[..comma].trim(), inner[comma + 1..].trim())
                }
                None => (inner.trim(), ""),
            };
            let Some(&rule) = RULES.iter().find(|r| **r == rule_txt) else {
                findings.push(Finding {
                    file: path.to_string(),
                    line: c.line,
                    rule: "unjustified-allow",
                    message: format!(
                        "allow() names unknown rule {rule_txt:?}"
                    ),
                });
                continue;
            };
            let justified = just.len() >= 2
                && just.starts_with('"')
                && just.ends_with('"')
                && !just[1..just.len() - 1].trim().is_empty();
            if justified {
                allowed.insert((rule, c.line));
                allowed.insert((rule, c.line + 1));
                ok = true;
            }
        }
        if !ok {
            findings.push(Finding {
                file: path.to_string(),
                line: c.line,
                rule: "unjustified-allow",
                message: "suppression needs a justification: \
                          // analysis: allow(<rule>, \"<why>\")"
                    .to_string(),
            });
        }
    }
    allowed
}

/// Analyze one source text under a root-relative `path` label.
/// Returns (unsuppressed findings, suppressed count).
pub fn analyze_source(
    path: &str,
    src: &str,
    active: &BTreeSet<String>,
) -> Result<(Vec<Finding>, usize)> {
    let (toks, comments) = lex::lex(src, path)?;
    let in_test = rules::mark_test_regions(&toks);
    let mut findings = Vec::new();
    let allowed = parse_suppressions(&comments, path, &mut findings);
    if !active.contains("unjustified-allow") {
        findings.clear();
    }
    let mut suppressed = 0;
    for f in rules::run_rules(path, &toks, &in_test, active) {
        if allowed.contains(&(f.rule, f.line)) {
            suppressed += 1;
        } else {
            findings.push(f);
        }
    }
    Ok((findings, suppressed))
}

/// Every `.rs` file under `root`, as sorted root-relative paths with
/// `/` separators (the rule-scoping path format).
pub fn discover(root: &Path) -> Result<Vec<String>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let entries = fs::read_dir(&dir)
            .map_err(|e| Error::io(dir.display().to_string(), e))?;
        for entry in entries {
            let entry = entry
                .map_err(|e| Error::io(dir.display().to_string(), e))?;
            let p = entry.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|x| x == "rs") {
                let rel: Vec<String> = p
                    .strip_prefix(root)
                    .map_err(|_| {
                        Error::Analysis(format!(
                            "{} escapes root {}",
                            p.display(),
                            root.display()
                        ))
                    })?
                    .components()
                    .map(|c| c.as_os_str().to_string_lossy().into_owned())
                    .collect();
                out.push(rel.join("/"));
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Run the pass over every `.rs` file under `root` with the active
/// rule set; findings come back sorted by (file, line, rule).
pub fn analyze_tree(
    root: &Path,
    active: &BTreeSet<String>,
) -> Result<Report> {
    if !root.is_dir() {
        return Err(Error::Analysis(format!(
            "source root {} is not a directory",
            root.display()
        )));
    }
    let mut findings = Vec::new();
    let mut suppressed = 0;
    for rel in discover(root)? {
        let full = root.join(&rel);
        let src = fs::read_to_string(&full)
            .map_err(|e| Error::io(full.display().to_string(), e))?;
        let (f, s) = analyze_source(&rel, &src, active)?;
        findings.extend(f);
        suppressed += s;
    }
    findings.sort();
    Ok(Report {
        findings,
        suppressed,
        rules: active.iter().cloned().collect(),
        root: root.display().to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all() -> BTreeSet<String> {
        active_rules(None).unwrap()
    }

    /// Run one fixture; returns (findings, suppressed).
    fn run(path: &str, src: &str) -> (Vec<Finding>, usize) {
        analyze_source(path, src, &all()).unwrap()
    }

    /// Assert `src` under `path` yields exactly one finding of `rule`,
    /// and that the same source with `allow_line` prepended suppresses
    /// it (one positive + one suppressed fixture per rule).
    fn positive_then_suppressed(path: &str, src: &str, rule: &str) {
        let (found, suppressed) = run(path, src);
        assert_eq!(
            found.len(),
            1,
            "{rule} positive fixture: {found:?}"
        );
        assert_eq!(found[0].rule, rule);
        assert_eq!(found[0].file, path);

        let allow = format!(
            "// analysis: allow({rule}, \"fixture: known-benign\")\n{src}"
        );
        let (found, suppressed2) = run(path, &allow);
        assert!(
            found.is_empty(),
            "{rule} suppressed fixture still fires: {found:?}"
        );
        assert_eq!(suppressed2, suppressed + 1);
    }

    #[test]
    fn unordered_emit_fixture() {
        positive_then_suppressed(
            "suite/fx.rs",
            "fn f(m: &HashMap<u32, u32>) -> usize { m.len() }\n",
            "unordered-emit",
        );
        // outside an emit module the same source is clean
        let (found, _) = run(
            "scheduler/fx.rs",
            "fn f(m: &HashMap<u32, u32>) -> usize { m.len() }\n",
        );
        assert!(found.is_empty());
    }

    #[test]
    fn wall_clock_fixture() {
        positive_then_suppressed(
            "scheduler/fx.rs",
            "fn f() -> Instant { Instant::now() }\n",
            "wall-clock-in-pure",
        );
        let (found, _) =
            run("runtime/fx.rs", "fn f() -> Instant { Instant::now() }\n");
        assert!(found.is_empty(), "runtime/ is allowlisted");
    }

    #[test]
    fn float_eq_fixture() {
        positive_then_suppressed(
            "metrics/fx.rs",
            "fn f(x: f64) -> bool { x == 1.0 }\n",
            "float-eq",
        );
        // integer comparison never fires
        let (found, _) =
            run("metrics/fx.rs", "fn f(x: u64) -> bool { x == 1 }\n");
        assert!(found.is_empty());
    }

    #[test]
    fn lossy_tick_cast_fixture() {
        positive_then_suppressed(
            "scheduler/fx.rs",
            "fn f(t: f64) -> Tick { t as Tick }\n",
            "lossy-tick-cast",
        );
        positive_then_suppressed(
            "loadtest/fx.rs",
            "fn f(t: f64) -> u64 { t.ceil() as u64 }\n",
            "lossy-tick-cast",
        );
        // plain widening casts outside the narrowing pattern are fine
        let (found, _) =
            run("scheduler/fx.rs", "fn f(t: u32) -> u64 { t as u64 }\n");
        assert!(found.is_empty());
    }

    #[test]
    fn relaxed_sync_fixture() {
        positive_then_suppressed(
            "coordinator/fx.rs",
            "fn f(c: &AtomicUsize) -> usize { c.load(Ordering::Relaxed) }\n",
            "relaxed-sync",
        );
        let (found, _) = run(
            "allocation/count.rs",
            "fn f(c: &AtomicUsize) -> usize { c.load(Ordering::Relaxed) }\n",
        );
        assert!(found.is_empty(), "the allocation counter is exempt");
    }

    #[test]
    fn unscoped_spawn_fixture() {
        positive_then_suppressed(
            "coordinator/fx.rs",
            "fn f() { std::thread::spawn(|| {}); }\n",
            "unscoped-spawn",
        );
        positive_then_suppressed(
            "coordinator/fx.rs",
            "fn f() { let b = std::thread::Builder::new(); }\n",
            "unscoped-spawn",
        );
        let (found, _) = run(
            "coordinator/fx.rs",
            "fn f() { std::thread::scope(|s| { s.spawn(|| {}); }); }\n",
        );
        assert!(found.is_empty(), "scoped pools are the blessed form");
    }

    #[test]
    fn bare_unwrap_fixture() {
        positive_then_suppressed(
            "scheduler/fx.rs",
            "fn f(v: Option<u32>) -> u32 { v.unwrap() }\n",
            "bare-unwrap",
        );
        positive_then_suppressed(
            "scheduler/fx.rs",
            "fn f(v: Option<u32>) -> u32 { v.expect(\"msg\") }\n",
            "bare-unwrap",
        );
        // a same-named parser method taking a non-string is not expect()
        let (found, _) = run(
            "serialize/fx.rs",
            "fn f(p: &mut P) { p.expect(b'{'); }\n",
        );
        assert!(found.is_empty(), "Parser::expect(b'..') is not flagged");
    }

    #[test]
    fn unjustified_allow_fixture() {
        // missing justification: the suppression itself is the finding
        let (found, _) = run(
            "scheduler/fx.rs",
            "// analysis: allow(bare-unwrap)\nfn f(v: Option<u32>) -> u32 { v.unwrap() }\n",
        );
        let rules: Vec<_> = found.iter().map(|f| f.rule).collect();
        assert!(rules.contains(&"unjustified-allow"), "{found:?}");
        assert!(
            rules.contains(&"bare-unwrap"),
            "an unjustified allow must not suppress: {found:?}"
        );

        // unknown rule name
        let (found, _) = run(
            "scheduler/fx.rs",
            "// analysis: allow(no-such-rule, \"why\")\nfn f() {}\n",
        );
        assert_eq!(found.len(), 1);
        assert_eq!(found[0].rule, "unjustified-allow");

        // a well-formed justified allow is itself clean
        let (found, _) = run(
            "scheduler/fx.rs",
            "// analysis: allow(float-eq, \"documented exact sentinel\")\nfn f(x: f64) -> bool { x == 1.0 }\n",
        );
        assert!(found.is_empty());
    }

    #[test]
    fn cfg_test_regions_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t(v: Option<u32>) -> u32 { v.unwrap() }\n}\n";
        let (found, _) = run("scheduler/fx.rs", src);
        assert!(found.is_empty(), "{found:?}");
    }

    #[test]
    fn report_renders_sorted_and_counts() {
        let src = "fn f(v: Option<u32>, x: f64) -> bool { v.unwrap(); x == 1.0 }\n";
        let (mut found, _) = run("metrics/fx.rs", src);
        found.sort();
        let report = Report {
            findings: found,
            suppressed: 0,
            rules: all().into_iter().collect(),
            root: "fixture".into(),
        };
        assert!(!report.clean());
        let text = report.render();
        assert!(text.contains("bare-unwrap"));
        assert!(text.contains("float-eq"));
        assert!(text.ends_with("2 finding(s), 0 suppressed, 8 rule(s) active\n"));
        let json = report.to_value().to_string_pretty();
        assert!(json.contains("\"bare-unwrap\": 1"));
        assert!(json.contains("\"float-eq\": 1"));
    }

    #[test]
    fn unknown_rule_csv_is_rejected() {
        assert!(active_rules(Some("float-eq,bogus")).is_err());
        assert!(active_rules(Some("")).is_err());
        let set = active_rules(Some("float-eq, bare-unwrap")).unwrap();
        assert_eq!(set.len(), 2);
    }

    /// The meta-test: the committed tree itself must pass `--check`
    /// with the full rule set — zero findings, zero unjustified
    /// suppressions.  (Fixing or justifying every violation is part of
    /// landing a rule; this pins that the tree stays clean.)
    #[test]
    #[cfg_attr(miri, ignore)] // walks and lexes the whole source tree
    fn committed_tree_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let report = analyze_tree(&root, &all()).unwrap();
        assert!(report.clean(), "\n{}", report.render());
        assert!(report.rules.len() >= 7, "at least 7 rules stay active");
        assert!(
            report.suppressed > 0,
            "the committed tree documents its justified exceptions"
        );
    }
}
