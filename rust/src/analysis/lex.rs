//! Token-level Rust lexer for the in-tree static-analysis pass.
//!
//! The crate already hand-rolls its TOML and JSON parsers; this is the
//! same idiom one layer down: enough lexical accuracy that strings,
//! raw strings, char literals vs lifetimes, and (nested) block
//! comments never leak tokens into rule matching, with a line number
//! on every token so findings point at real source lines.
//!
//! Known benign inaccuracies (shared with the Python mirror,
//! `python/tools/analyze_mirror.py`): raw identifiers (`r#type`) lex
//! as ident+punct+ident, and nested tuple access (`x.0.1`) lexes its
//! tail as a float literal — neither reaches any rule.

use crate::error::{Error, Result};

/// Token classes the rule engine matches on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Identifier or keyword (`HashMap`, `as`, `thread`).
    Ident,
    /// Integer-shaped numeric literal (`42`, `0x1f`, `1_000u64`).
    Num,
    /// Float-shaped numeric literal (`1.0`, `1e9`, `3f64`).
    FNum,
    /// Any string literal (cooked, raw, byte); contents are dropped.
    Str,
    /// Char or byte-char literal (`'x'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// Everything else, with two-char operators joined (`::`, `==`).
    Punct,
}

/// One lexed token: kind, source text, and 1-based line number.
/// String literals carry empty text — no rule matches their contents,
/// and dropping them keeps fixture sources from tripping rules.
#[derive(Clone, Copy, Debug)]
pub struct Tok<'a> {
    pub kind: Kind,
    pub text: &'a str,
    pub line: u32,
}

/// One `//` line comment (text excludes the slashes), for the
/// suppression parser.  Block comments are discarded entirely:
/// suppressions must be line comments.
#[derive(Clone, Copy, Debug)]
pub struct Comment<'a> {
    pub line: u32,
    pub text: &'a str,
}

/// Two-character operators lexed as one punct token.  Order is
/// irrelevant (no member is a prefix of another).
const JOINED_PUNCT: [&str; 10] =
    ["::", "==", "!=", "<=", ">=", "->", "=>", "..", "&&", "||"];

/// Tokenize `src`; `path` only labels lex errors.
pub fn lex<'a>(
    src: &'a str,
    path: &str,
) -> Result<(Vec<Tok<'a>>, Vec<Comment<'a>>)> {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    let err = |msg: &str, at: u32| {
        Error::Analysis(format!("{path}:{at}: {msg}"))
    };

    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        if src[i..].starts_with("//") {
            let j = src[i..].find('\n').map_or(n, |k| i + k);
            comments.push(Comment { line, text: &src[i + 2..j] });
            i = j;
            continue;
        }
        if src[i..].starts_with("/*") {
            let start = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if src[i..].starts_with("/*") {
                    depth += 1;
                    i += 2;
                } else if src[i..].starts_with("*/") {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            if depth > 0 {
                return Err(err("unterminated block comment", start));
            }
            continue;
        }
        if c == b'r' || c == b'b' {
            if let Some(hashes) = raw_str_hashes(&src[i..]) {
                let start = line;
                let prefix = if src[i..].starts_with("br") { 2 } else { 1 };
                let body = i + prefix + hashes + 1;
                let terminator = format!("\"{}", "#".repeat(hashes));
                let Some(k) = src[body..].find(&terminator) else {
                    return Err(err("unterminated raw string", start));
                };
                let k = body + k;
                line += src[body..k].matches('\n').count() as u32;
                toks.push(Tok { kind: Kind::Str, text: "", line: start });
                i = k + terminator.len();
                continue;
            }
            if src[i..].starts_with("b\"") {
                let start = line;
                let (j, nl) = cooked_string(src, i + 1, line)
                    .ok_or_else(|| err("unterminated string", line))?;
                line = nl;
                toks.push(Tok { kind: Kind::Str, text: "", line: start });
                i = j;
                continue;
            }
            if src[i..].starts_with("b'") {
                let (j, tok) = char_or_lifetime(src, i + 1, line)
                    .ok_or_else(|| err("unterminated char literal", line))?;
                toks.push(tok);
                i = j;
                continue;
            }
        }
        if c == b'"' {
            let start = line;
            let (j, nl) = cooked_string(src, i, line)
                .ok_or_else(|| err("unterminated string", line))?;
            line = nl;
            toks.push(Tok { kind: Kind::Str, text: "", line: start });
            i = j;
            continue;
        }
        if c == b'\'' {
            let (j, tok) = char_or_lifetime(src, i, line)
                .ok_or_else(|| err("unterminated char literal", line))?;
            toks.push(tok);
            i = j;
            continue;
        }
        if c.is_ascii_alphabetic() || c == b'_' {
            let mut j = i;
            while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                j += 1;
            }
            toks.push(Tok { kind: Kind::Ident, text: &src[i..j], line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let (j, tok) = number(src, i, line);
            toks.push(tok);
            i = j;
            continue;
        }
        match JOINED_PUNCT.iter().find(|op| src[i..].starts_with(**op)) {
            Some(op) => {
                toks.push(Tok { kind: Kind::Punct, text: op, line });
                i += op.len();
            }
            None => {
                // one char — by *character*, so a stray non-ASCII byte
                // sequence outside strings advances past the whole char
                let w = src[i..].chars().next().map_or(1, char::len_utf8);
                toks.push(Tok {
                    kind: Kind::Punct,
                    text: &src[i..i + w],
                    line,
                });
                i += w;
            }
        }
    }
    Ok((toks, comments))
}

/// `r"…"` / `r#"…"#` / `br#"…"#` opener at the start of `s`: returns
/// the hash count.  `rb"` is not a Rust prefix and returns None (it
/// lexes as the ident `rb` followed by a cooked string).
fn raw_str_hashes(s: &str) -> Option<usize> {
    let t = s
        .strip_prefix("br")
        .or_else(|| s.strip_prefix('r'))?
        .as_bytes();
    let h = t.iter().take_while(|&&c| c == b'#').count();
    (t.get(h) == Some(&b'"')).then_some(h)
}

/// Scan a cooked string from its opening quote at byte `i`; returns
/// (index past the closing quote, updated line) or None when
/// unterminated.
fn cooked_string(src: &str, i: usize, mut line: u32) -> Option<(usize, u32)> {
    let b = src.as_bytes();
    let n = b.len();
    let mut j = i + 1;
    while j < n {
        match b[j] {
            b'\\' => {
                // the escaped char may itself be a newline (line
                // continuation inside a multi-line string)
                if j + 1 < n && b[j + 1] == b'\n' {
                    line += 1;
                }
                j += 2;
            }
            b'\n' => {
                line += 1;
                j += 1;
            }
            b'"' => return Some((j + 1, line)),
            _ => j += 1,
        }
    }
    None
}

/// From an opening single quote at byte `i`: a lifetime (`'a`,
/// `'static`) or a char literal (`'x'`, `'\n'`, `'\u{..}'`).  Returns
/// (index past the token, token) or None when unterminated.
fn char_or_lifetime(src: &str, i: usize, line: u32) -> Option<(usize, Tok)> {
    let b = src.as_bytes();
    let n = b.len();
    let nxt = b.get(i + 1).copied().unwrap_or(0);
    let after = b.get(i + 2).copied().unwrap_or(0);
    if (nxt.is_ascii_alphabetic() || nxt == b'_') && after != b'\'' {
        let mut j = i + 1;
        while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
            j += 1;
        }
        let tok = Tok { kind: Kind::Lifetime, text: &src[i..j], line };
        return Some((j, tok));
    }
    let mut j = i + 1;
    if j < n && b[j] == b'\\' {
        j += 1;
        if j < n && b[j] == b'u' {
            j = src[j..].find('}').map(|k| j + k)?;
        }
        j += 1;
    } else if j < n {
        j += src[j..].chars().next().map_or(1, char::len_utf8);
    }
    if j >= n || b[j] != b'\'' {
        return None;
    }
    let tok = Tok { kind: Kind::Char, text: &src[i..j + 1], line };
    Some((j + 1, tok))
}

/// Lex a numeric literal starting at a digit.  The `e`/`E` handling
/// lets exponent signs (`1e-9`, `2.5E+3`) stay part of the token.
fn number(src: &str, i: usize, line: u32) -> (usize, Tok) {
    let b = src.as_bytes();
    let n = b.len();
    let hex = src[i..].len() >= 2 && b[i] == b'0' && (b[i + 1] | 0x20) == b'x';
    let mut j = i;
    while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
        j += 1;
        if (b[j - 1] | 0x20) == b'e'
            && !hex
            && j < n
            && (b[j] == b'+' || b[j] == b'-')
            && j + 1 < n
            && b[j + 1].is_ascii_digit()
        {
            j += 1;
        }
    }
    if j < n
        && b[j] == b'.'
        && !src[j..].starts_with("..")
        && !(j + 1 < n && (b[j + 1].is_ascii_alphabetic() || b[j + 1] == b'_'))
    {
        j += 1;
        while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
            j += 1;
            if (b[j - 1] | 0x20) == b'e'
                && j < n
                && (b[j] == b'+' || b[j] == b'-')
                && j + 1 < n
                && b[j + 1].is_ascii_digit()
            {
                j += 1;
            }
        }
    }
    let text = &src[i..j];
    let kind = if is_float_literal(text) { Kind::FNum } else { Kind::Num };
    (j, Tok { kind, text, line })
}

/// Float-literal shape test over a whole numeric token: digits with a
/// decimal point, an exponent, or an `f32`/`f64` suffix.  (`1` and
/// `0x1f` are Num; `1.0`, `1e9`, `1.`, and `3f64` are FNum.)
fn is_float_literal(t: &str) -> bool {
    let b = t.as_bytes();
    if b.is_empty() || !b[0].is_ascii_digit() {
        return false;
    }
    let mut i = 0usize;
    while i < b.len() && (b[i].is_ascii_digit() || b[i] == b'_') {
        i += 1;
    }
    let mut floatish = false;
    if i < b.len() && b[i] == b'.' {
        i += 1;
        floatish = true;
        if i < b.len() && b[i].is_ascii_digit() {
            while i < b.len() && (b[i].is_ascii_digit() || b[i] == b'_') {
                i += 1;
            }
        }
    }
    if i < b.len() && (b[i] | 0x20) == b'e' {
        let mut j = i + 1;
        if j < b.len() && (b[j] == b'+' || b[j] == b'-') {
            j += 1;
        }
        let digits = j;
        while j < b.len() && (b[j].is_ascii_digit() || b[j] == b'_') {
            j += 1;
        }
        if j > digits {
            i = j;
            floatish = true;
        }
    }
    match &t[i..] {
        "" => floatish,
        "f32" | "f64" => true,
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        let (toks, _) = lex(src, "fixture.rs").unwrap();
        toks.iter().map(|t| (t.kind, t.text.to_string())).collect()
    }

    #[test]
    fn raw_strings_hide_comment_markers_and_quotes() {
        let src = r##"let s = r#"not a // comment, "quoted""#;"##;
        let (toks, comments) = lex(src, "fixture.rs").unwrap();
        assert!(comments.is_empty());
        let texts: Vec<_> = toks.iter().map(|t| t.text).collect();
        assert_eq!(texts, ["let", "s", "=", "", ";"]);
        assert_eq!(toks[3].kind, Kind::Str);
    }

    #[test]
    fn byte_literals_lex_as_strings_and_chars() {
        let got = kinds(r#"(b"bytes", br"raw", b'x', rb_ident)"#);
        let want = [
            (Kind::Punct, "("),
            (Kind::Str, ""),
            (Kind::Punct, ","),
            (Kind::Str, ""),
            (Kind::Punct, ","),
            (Kind::Char, "'x'"),
            (Kind::Punct, ","),
            (Kind::Ident, "rb_ident"),
            (Kind::Punct, ")"),
        ];
        assert_eq!(
            got,
            want.map(|(k, t)| (k, t.to_string())).to_vec()
        );
    }

    #[test]
    fn nested_block_comments_balance() {
        let src = "/* outer /* inner */ still outer */ fn f() {}";
        let (toks, comments) = lex(src, "fixture.rs").unwrap();
        assert!(comments.is_empty());
        assert_eq!(toks[0].text, "fn");
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let got = kinds(r"<'a> &'static str; 'x' '\n' '\u{1F600}'");
        let lifetimes: Vec<_> = got
            .iter()
            .filter(|(k, _)| *k == Kind::Lifetime)
            .map(|(_, t)| t.as_str())
            .collect();
        let chars: Vec<_> = got
            .iter()
            .filter(|(k, _)| *k == Kind::Char)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(lifetimes, ["'a", "'static"]);
        assert_eq!(chars, ["'x'", r"'\n'", r"'\u{1F600}'"]);
    }

    #[test]
    fn numeric_classification() {
        let got = kinds("1 1_000u64 0x1f 1.0 1. 1e9 2.5E+3 3f64 9e-2");
        let nums: Vec<_> =
            got.iter().map(|(k, t)| (*k, t.as_str())).collect();
        assert_eq!(
            nums,
            [
                (Kind::Num, "1"),
                (Kind::Num, "1_000u64"),
                (Kind::Num, "0x1f"),
                (Kind::FNum, "1.0"),
                (Kind::FNum, "1."),
                (Kind::FNum, "1e9"),
                (Kind::FNum, "2.5E+3"),
                (Kind::FNum, "3f64"),
                (Kind::FNum, "9e-2"),
            ]
        );
    }

    #[test]
    fn joined_punct_and_ranges() {
        let got = kinds("a::b == c -> d .. e && 0..n");
        let puncts: Vec<_> = got
            .iter()
            .filter(|(k, _)| *k == Kind::Punct)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(puncts, ["::", "==", "->", "..", "&&", ".."]);
    }

    #[test]
    fn line_numbers_survive_string_continuations() {
        let src = "let a = \"x\\\n  y\";\nfn b() {}\n";
        let (toks, _) = lex(src, "fixture.rs").unwrap();
        let fn_tok = toks.iter().find(|t| t.text == "fn").unwrap();
        assert_eq!(fn_tok.line, 3);
    }

    #[test]
    fn comments_collect_text_and_line() {
        let src = "// first\nlet x = 1; // analysis: allow(float-eq, \"y\")\n";
        let (_, comments) = lex(src, "fixture.rs").unwrap();
        assert_eq!(comments.len(), 2);
        assert_eq!(comments[0].line, 1);
        assert_eq!(comments[0].text, " first");
        assert_eq!(comments[1].line, 2);
        assert!(comments[1].text.contains("analysis: allow"));
    }

    #[test]
    fn unterminated_inputs_error() {
        assert!(lex("\"open", "f.rs").is_err());
        assert!(lex("r#\"open", "f.rs").is_err());
        assert!(lex("/* open", "f.rs").is_err());
        assert!(lex("'", "f.rs").is_err());
    }
}
