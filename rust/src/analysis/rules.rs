//! Rule engine: the determinism & concurrency rule set the golden
//! corpus depends on, over the token stream from [`super::lex`].
//!
//! This file is the normative statement of every rule and every
//! scoping decision; `python/tools/analyze_mirror.py` is an
//! independent from-scratch mirror (like `suite_oracle.py` for the
//! scenario pipeline) and must be kept in lockstep when a rule is
//! added or re-scoped.
//!
//! Paths are relative to the source root (`rust/src`), always with
//! `/` separators.  Tokens inside `#[cfg(test)]` items never match:
//! tests may unwrap, compare floats, and spawn freely.

use std::collections::BTreeSet;

use super::lex::{Kind, Tok};

/// Every rule name, in report order.  `unjustified-allow` is the
/// meta-rule: a malformed or justification-free suppression comment is
/// itself a finding.
pub const RULES: [&str; 8] = [
    "unordered-emit",
    "wall-clock-in-pure",
    "float-eq",
    "lossy-tick-cast",
    "relaxed-sync",
    "unscoped-spawn",
    "bare-unwrap",
    "unjustified-allow",
];

/// Modules whose output feeds `write_value` or a rendered report:
/// iteration order inside them must be deterministic, so `HashMap` /
/// `HashSet` are banned in favor of the B-tree forms (or an explicit
/// sort before emitting).
const EMIT_MODULES: [&str; 7] = [
    "benchkit/",
    "loadtest/",
    "metrics/",
    "metro/",
    "report/",
    "serialize/",
    "suite/",
];

/// The real-time allowlist for `wall-clock-in-pure`: the Instant-keyed
/// delay queue, the CLI binary, the PJRT runtime, and the measurement
/// harness are *supposed* to read the clock.  Everything else —
/// notably the virtual-time loadtest and every solver — must not.
const WALL_CLOCK_ALLOWED_FILES: [&str; 2] = ["coordinator/delay.rs", "main.rs"];
const WALL_CLOCK_ALLOWED_DIRS: [&str; 2] = ["runtime/", "benchkit/"];

/// Modules where `lossy-tick-cast` applies: everywhere ticks are
/// computed or consumed.  `scale_ticks` (topology) is the blessed
/// conversion primitive; ad-hoc `as Tick` casts need a justification.
const TICK_CAST_MODULES: [&str; 5] = [
    "coordinator/",
    "loadtest/",
    "scenario/",
    "scheduler/",
    "topology/",
];

/// `f()` sources whose result is wider than (or real-valued next to)
/// the integer it is cast into — `x.ceil() as u64` and friends.
const NARROWING_SOURCES: [&str; 7] = [
    "ceil",
    "round",
    "floor",
    "as_nanos",
    "as_micros",
    "as_millis",
    "as_secs_f64",
];

/// Narrow integer cast targets the `lossy-tick-cast` rule watches.
const NARROW_INTS: [&str; 6] = ["u64", "u32", "usize", "i64", "i32", "Tick"];

/// One finding; `Ord` gives the deterministic (file, line, rule)
/// report order.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub message: String,
}

/// Per-token flag: inside an item annotated `#[cfg(test)]` — the
/// attribute through the end of the annotated item (its balanced
/// `{...}` block, or a top-level `;` for brace-less items like the
/// lib's `#[cfg(test)] #[global_allocator] static ...;`).
pub fn mark_test_regions(toks: &[Tok]) -> Vec<bool> {
    let mut in_test = vec![false; toks.len()];
    for i in 0..toks.len() {
        let is_cfg_test = toks[i].text == "#"
            && i + 5 < toks.len()
            && toks[i + 1].text == "["
            && toks[i + 2].text == "cfg"
            && toks[i + 3].text == "("
            && toks[i + 4].text == "test"
            && toks[i + 5].text == ")";
        if !is_cfg_test {
            continue;
        }
        let mut j = i + 6;
        while j < toks.len() && toks[j].text != "]" {
            j += 1;
        }
        let mut brace = 0i64;
        let mut k = j + 1;
        while k < toks.len() {
            match toks[k].text {
                "{" => brace += 1,
                "}" => {
                    brace -= 1;
                    if brace == 0 {
                        break;
                    }
                }
                ";" if brace == 0 => break,
                _ => {}
            }
            k += 1;
        }
        let end = (k + 1).min(toks.len());
        for flag in &mut in_test[i..end] {
            *flag = true;
        }
    }
    in_test
}

fn in_dirs(path: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| path.starts_with(p))
}

/// Run every active rule over one file's tokens.  Suppressions are the
/// caller's job ([`super::analyze_source`]); this returns raw matches.
pub fn run_rules(
    path: &str,
    toks: &[Tok],
    in_test: &[bool],
    active: &BTreeSet<String>,
) -> Vec<Finding> {
    const NIL: Tok<'static> =
        Tok { kind: Kind::Punct, text: "", line: 0 };
    let mut findings: Vec<Finding> = Vec::new();
    let mut emit = |rule: &'static str, line: u32, message: String| {
        findings.push(Finding { file: path.to_string(), line, rule, message });
    };

    for i in 0..toks.len() {
        if in_test[i] {
            continue;
        }
        let t = toks[i];
        let nxt = |k: usize| toks.get(i + k).copied().unwrap_or(NIL);
        let prv = |k: usize| {
            if i >= k {
                toks[i - k]
            } else {
                NIL
            }
        };

        if active.contains("unordered-emit")
            && t.kind == Kind::Ident
            && (t.text == "HashMap" || t.text == "HashSet")
            && in_dirs(path, &EMIT_MODULES)
        {
            emit(
                "unordered-emit",
                t.line,
                format!(
                    "{} in a report-emitting module: iteration order is \
                     nondeterministic; use BTreeMap/BTreeSet or sort \
                     before emitting",
                    t.text
                ),
            );
        }
        if active.contains("wall-clock-in-pure")
            && t.kind == Kind::Ident
            && !WALL_CLOCK_ALLOWED_FILES.contains(&path)
            && !in_dirs(path, &WALL_CLOCK_ALLOWED_DIRS)
        {
            if t.text == "Instant"
                && nxt(1).text == "::"
                && nxt(2).text == "now"
            {
                emit(
                    "wall-clock-in-pure",
                    t.line,
                    "Instant::now() outside the real-time allowlist: \
                     wall-clock reads make results machine-dependent"
                        .to_string(),
                );
            } else if t.text == "SystemTime" {
                emit(
                    "wall-clock-in-pure",
                    t.line,
                    "SystemTime outside the real-time allowlist: \
                     wall-clock reads make results machine-dependent"
                        .to_string(),
                );
            }
        }
        if active.contains("float-eq")
            && t.kind == Kind::Punct
            && (t.text == "==" || t.text == "!=")
            && (prv(1).kind == Kind::FNum || nxt(1).kind == Kind::FNum)
        {
            emit(
                "float-eq",
                t.line,
                format!(
                    "{} against a float literal: exact float comparison \
                     is representation-sensitive; compare integers, \
                     bits, or a documented exact set",
                    t.text
                ),
            );
        }
        if active.contains("lossy-tick-cast")
            && t.kind == Kind::Ident
            && t.text == "as"
            && in_dirs(path, &TICK_CAST_MODULES)
        {
            let target = nxt(1).text;
            if target == "Tick" {
                emit(
                    "lossy-tick-cast",
                    t.line,
                    "`as Tick` cast: silent truncation/saturation; use \
                     scale_ticks or a checked conversion"
                        .to_string(),
                );
            } else if NARROW_INTS.contains(&target)
                && prv(1).text == ")"
                && prv(2).text == "("
                && prv(3).kind == Kind::Ident
                && NARROWING_SOURCES.contains(&prv(3).text)
            {
                emit(
                    "lossy-tick-cast",
                    t.line,
                    format!(
                        "`{}() as {}` narrows a wider value: silent \
                         truncation on overflow",
                        prv(3).text,
                        target
                    ),
                );
            }
        }
        if active.contains("relaxed-sync")
            && t.kind == Kind::Ident
            && t.text == "Ordering"
            && nxt(1).text == "::"
            && nxt(2).text == "Relaxed"
            && path != "allocation/count.rs"
        {
            emit(
                "relaxed-sync",
                t.line,
                "Ordering::Relaxed outside a pure counter: state an \
                 explicit happens-before edge (Acquire/Release) or \
                 justify why none is needed"
                    .to_string(),
            );
        }
        if active.contains("unscoped-spawn")
            && t.kind == Kind::Ident
            && t.text == "thread"
            && nxt(1).text == "::"
            && (nxt(2).text == "spawn" || nxt(2).text == "Builder")
            && !path.starts_with("runtime/")
        {
            emit(
                "unscoped-spawn",
                t.line,
                format!(
                    "unscoped thread (thread::{}) outside runtime/: \
                     prefer std::thread::scope, or justify the join \
                     point",
                    nxt(2).text
                ),
            );
        }
        if active.contains("bare-unwrap")
            && t.kind == Kind::Punct
            && t.text == "."
            && path != "main.rs"
        {
            let name = nxt(1);
            if name.kind == Kind::Ident
                && name.text == "unwrap"
                && nxt(2).text == "("
                && nxt(3).text == ")"
            {
                emit(
                    "bare-unwrap",
                    name.line,
                    ".unwrap() in library code: return a typed Error or \
                     justify the locally-provable invariant"
                        .to_string(),
                );
            } else if name.kind == Kind::Ident
                && name.text == "expect"
                && nxt(2).text == "("
                // the string-literal argument is what distinguishes
                // Option/Result::expect("msg") from same-named methods
                // (the JSON parser's Parser::expect(b'{')).
                && nxt(3).kind == Kind::Str
            {
                emit(
                    "bare-unwrap",
                    name.line,
                    ".expect() in library code: return a typed Error or \
                     justify the locally-provable invariant"
                        .to_string(),
                );
            }
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lex::lex;

    fn marks(src: &str) -> (Vec<String>, Vec<bool>) {
        let (toks, _) = lex(src, "fixture.rs").unwrap();
        let flags = mark_test_regions(&toks);
        let texts = toks.iter().map(|t| t.text.to_string()).collect();
        (texts, flags)
    }

    #[test]
    fn cfg_test_marks_braced_items() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let (texts, flags) = marks(src);
        let flag_of = |needle: &str| {
            let i = texts.iter().position(|t| t == needle).unwrap();
            flags[i]
        };
        assert!(!flag_of("live"));
        assert!(flag_of("tests"));
        assert!(flag_of("t"));
        assert!(!flag_of("after"));
    }

    #[test]
    fn cfg_test_marks_braceless_statics() {
        // the lib.rs pattern: an annotated static with no brace block
        let src = "#[cfg(test)]\n#[global_allocator]\nstatic A: B = B;\nfn after() {}\n";
        let (texts, flags) = marks(src);
        let a = texts.iter().position(|t| t == "A").unwrap();
        let after = texts.iter().position(|t| t == "after").unwrap();
        assert!(flags[a]);
        assert!(!flags[after]);
    }

    #[test]
    fn cfg_test_attr_with_args_is_not_a_region() {
        // #[cfg(test)] only — cfg(feature = "test") etc. must not match
        let src = "#[cfg(feature = \"x\")]\nfn f(v: Option<u32>) { v.unwrap(); }\n";
        let (_, flags) = marks(src);
        assert!(flags.iter().all(|f| !f));
    }
}
