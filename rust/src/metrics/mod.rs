//! Serving metrics: latency summaries, throughput counters, per-layer
//! utilization — the numbers the E2E driver reports.

mod summary;

pub use summary::LatencySummary;

use std::collections::BTreeMap;
use std::time::Duration;


use crate::device::Layer;
use crate::serialize::Value;

/// Accumulates per-layer request metrics during a serving run.
#[derive(Debug, Default, Clone)]
pub struct MetricsRegistry {
    per_layer: BTreeMap<Layer, LayerMetrics>,
    started_at_ms: f64,
    finished_at_ms: f64,
}

#[derive(Debug, Default, Clone)]
struct LayerMetrics {
    latencies_ms: Vec<f64>,
    transmission_ms: Vec<f64>,
    processing_ms: Vec<f64>,
    queue_ms: Vec<f64>,
    requests: u64,
    batches: u64,
    batched_rows: u64,
}

/// Immutable snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub per_layer: BTreeMap<String, LayerReport>,
    pub total_requests: u64,
    pub wall_time_s: f64,
    pub throughput_rps: f64,
}

#[derive(Debug, Clone)]
pub struct LayerReport {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub latency: LatencySummary,
    pub transmission: LatencySummary,
    pub processing: LatencySummary,
    pub queueing: LatencySummary,
}

impl MetricsReport {
    /// JSON rendering.
    pub fn to_value(&self) -> Value {
        let mut v = Value::object();
        v.set("total_requests", self.total_requests);
        v.set("wall_time_s", self.wall_time_s);
        v.set("throughput_rps", self.throughput_rps);
        let mut layers = Value::object();
        for (name, rep) in &self.per_layer {
            layers.set(name, rep.to_value());
        }
        v.set("per_layer", layers);
        v
    }
}

impl LayerReport {
    /// JSON rendering.
    pub fn to_value(&self) -> Value {
        let mut v = Value::object();
        v.set("requests", self.requests);
        v.set("batches", self.batches);
        v.set("mean_batch", self.mean_batch);
        v.set("latency_ms", self.latency.to_value());
        v.set("transmission_ms", self.transmission.to_value());
        v.set("processing_ms", self.processing.to_value());
        v.set("queueing_ms", self.queueing.to_value());
        v
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mark run boundaries (ms on any monotone clock).
    pub fn set_window(&mut self, start_ms: f64, end_ms: f64) {
        self.started_at_ms = start_ms;
        self.finished_at_ms = end_ms;
    }

    /// Record one completed request.
    pub fn record_request(
        &mut self,
        layer: Layer,
        total: Duration,
        transmission: Duration,
        queueing: Duration,
        processing: Duration,
    ) {
        let m = self.per_layer.entry(layer).or_default();
        m.requests += 1;
        m.latencies_ms.push(total.as_secs_f64() * 1e3);
        m.transmission_ms.push(transmission.as_secs_f64() * 1e3);
        m.queue_ms.push(queueing.as_secs_f64() * 1e3);
        m.processing_ms.push(processing.as_secs_f64() * 1e3);
    }

    /// Record one executed batch of `rows` requests.
    pub fn record_batch(&mut self, layer: Layer, rows: usize) {
        let m = self.per_layer.entry(layer).or_default();
        m.batches += 1;
        m.batched_rows += rows as u64;
    }

    pub fn total_requests(&self) -> u64 {
        self.per_layer.values().map(|m| m.requests).sum()
    }

    /// Build the reporting snapshot.
    pub fn report(&self) -> MetricsReport {
        let wall = ((self.finished_at_ms - self.started_at_ms) / 1e3).max(0.0);
        let total = self.total_requests();
        MetricsReport {
            per_layer: self
                .per_layer
                .iter()
                .map(|(l, m)| {
                    (
                        l.abbrev().to_string(),
                        LayerReport {
                            requests: m.requests,
                            batches: m.batches,
                            mean_batch: if m.batches == 0 {
                                0.0
                            } else {
                                m.batched_rows as f64 / m.batches as f64
                            },
                            latency: LatencySummary::from_samples(&m.latencies_ms),
                            transmission: LatencySummary::from_samples(&m.transmission_ms),
                            processing: LatencySummary::from_samples(&m.processing_ms),
                            queueing: LatencySummary::from_samples(&m.queue_ms),
                        },
                    )
                })
                .collect(),
            total_requests: total,
            wall_time_s: wall,
            throughput_rps: if wall > 0.0 { total as f64 / wall } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_report() {
        let mut r = MetricsRegistry::new();
        r.set_window(0.0, 2000.0);
        for i in 1..=10 {
            r.record_request(
                Layer::Edge,
                Duration::from_millis(10 * i),
                Duration::from_millis(2),
                Duration::from_millis(1),
                Duration::from_millis(5),
            );
        }
        r.record_batch(Layer::Edge, 10);
        let rep = r.report();
        assert_eq!(rep.total_requests, 10);
        assert!((rep.throughput_rps - 5.0).abs() < 1e-9);
        let edge = &rep.per_layer["ES"];
        assert_eq!(edge.requests, 10);
        assert!((edge.mean_batch - 10.0).abs() < 1e-9);
        assert!((edge.latency.mean - 55.0).abs() < 1e-9);
    }

    #[test]
    fn empty_report() {
        let rep = MetricsRegistry::new().report();
        assert_eq!(rep.total_requests, 0);
        assert_eq!(rep.throughput_rps, 0.0);
    }
}
