//! Latency distribution summary (mean / p50 / p95 / p99 / max).


/// Summary statistics over a sample set (milliseconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySummary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl LatencySummary {
    /// JSON rendering.
    pub fn to_value(&self) -> crate::serialize::Value {
        let mut v = crate::serialize::Value::object();
        v.set("count", self.count);
        v.set("mean", self.mean);
        v.set("p50", self.p50);
        v.set("p95", self.p95);
        v.set("p99", self.p99);
        v.set("min", self.min);
        v.set("max", self.max);
        v
    }

    /// Compute from integer tick samples (scheduler response times) —
    /// the bridge between the discrete-event simulator and the serving
    /// metrics vocabulary, used by the scenario-suite matrix.
    pub fn from_ticks(samples: &[u64]) -> Self {
        let as_f64: Vec<f64> =
            samples.iter().map(|&t| t as f64).collect();
        Self::from_samples(&as_f64)
    }

    /// Compute from raw samples (order irrelevant).
    pub fn from_samples(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self::default();
        }
        // total_cmp: no NaN panic, and one defined order for every
        // input — the summary stays deterministic even on junk samples
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(f64::total_cmp);
        let n = sorted.len();
        LatencySummary {
            count: n,
            mean: sorted.iter().sum::<f64>() / n as f64,
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
            min: sorted[0],
            max: sorted[n - 1],
        }
    }
}

/// Nearest-rank percentile on a pre-sorted slice.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let idx = ((sorted.len() as f64) * q).ceil() as usize;
    sorted[idx.clamp(1, sorted.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_percentiles() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = LatencySummary::from_samples(&samples);
        assert_eq!(s.count, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.p99, 99.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn single_sample() {
        let s = LatencySummary::from_samples(&[7.0]);
        assert_eq!(s.p50, 7.0);
        assert_eq!(s.p99, 7.0);
        assert_eq!(s.mean, 7.0);
    }

    #[test]
    fn empty_is_default() {
        let s = LatencySummary::from_samples(&[]);
        assert_eq!(s.count, 0);
        assert_eq!(s.max, 0.0);
    }

    #[test]
    fn unsorted_input() {
        let s = LatencySummary::from_samples(&[3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn ticks_match_f64_samples() {
        let ticks: Vec<u64> = (1..=40).collect();
        let floats: Vec<f64> = ticks.iter().map(|&t| t as f64).collect();
        let a = LatencySummary::from_ticks(&ticks);
        let b = LatencySummary::from_samples(&floats);
        assert_eq!(a.p95, b.p95);
        assert_eq!(a.p95, 38.0);
        assert_eq!(LatencySummary::from_ticks(&[]).p95, 0.0);
    }
}
