//! First-class scheduling scenarios: the library's polymorphic front
//! door.
//!
//! A [`Scenario`] bundles everything a solver needs — a job set (literal,
//! or realized from a seeded [`Arrival`] process), a
//! [`Topology`](crate::topology::Topology), an [`Objective`], and the
//! scheduler tunables — and every strategy behind the [`Solver`] trait
//! consumes one.  [`Scenario::paper`] is the paper's experiment (Table VI
//! trace, 1-cloud + 1-edge, eq. 5) and reproduces Table VII bit-for-bit
//! through the registry; everything else is a builder call away:
//!
//! ```
//! use edgeward::scenario::{Arrival, Objective, Scenario};
//! use edgeward::topology::Topology;
//!
//! // Table VII's all-edge row through the registry
//! let paper = Scenario::paper();
//! assert_eq!(paper.solve("all-edge")?.unweighted_sum(), 291);
//!
//! // a Poisson ward, two edge servers, minimizing makespan
//! let ward = Scenario::builder()
//!     .arrival(Arrival::PoissonWard { jobs: 12, rate: 0.25 })
//!     .seed(7)
//!     .topology(Topology::try_new(1, 2)?)
//!     .objective(Objective::Makespan)
//!     .build()?;
//! let best = ward.solve("tabu")?;
//! assert!(ward.evaluate(&best) <= ward.evaluate(&ward.solve("greedy")?));
//! # Ok::<(), edgeward::Error>(())
//! ```

mod arrival;
mod objective;
mod solver;

pub use arrival::Arrival;
pub use objective::Objective;
pub use solver::{
    solver, solver_names, solver_spec, Solver, SolverSpec, SOLVERS,
};

use crate::config::FieldReader;
use crate::scheduler::{Job, Schedule, SchedulerParams};
use crate::serialize::Value;
use crate::topology::Topology;
use crate::{Error, Result};

/// A fully-specified scheduling problem instance.
///
/// Construct via [`Scenario::builder`], [`Scenario::paper`], or a TOML
/// `[scenario]` section ([`Scenario::load`]).  Fields are public for
/// inspection; mutate through the builder so validation stays in one
/// place (solvers re-run [`Scenario::validate`] defensively).
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Display name (defaults to the arrival-process key).
    pub name: String,
    /// The realized job set.
    pub jobs: Vec<Job>,
    /// The arrival process the jobs came from (`None` for literal job
    /// lists).
    pub arrival: Option<Arrival>,
    /// The seed the arrival process was realized with.
    pub seed: u64,
    /// The machine set.
    pub topology: Topology,
    /// What solvers minimize.
    pub objective: Objective,
    /// Algorithm 2 tunables (used by the tabu solver).
    pub params: SchedulerParams,
}

impl Scenario {
    /// Start building a scenario (paper topology, paper trace, eq. 5
    /// objective unless overridden).
    pub fn builder() -> ScenarioBuilder {
        ScenarioBuilder::default()
    }

    /// The paper's scheduling experiment: Table VI trace on the 1-cloud +
    /// 1-edge topology under the eq.-5 objective.  Every solver in the
    /// registry reproduces its published Table VII row on this scenario.
    pub fn paper() -> Scenario {
        Scenario::builder()
            .name("paper")
            .build()
            // analysis: allow(bare-unwrap, "the committed Table VI trace always passes builder validation")
            .expect("paper scenario is always valid")
    }

    /// Solve with a registry solver (`"tabu"`, `"exact"`, `"all-edge"`,
    /// ... — see [`solver_names`]).
    pub fn solve(&self, solver_name: &str) -> Result<Schedule> {
        solver(solver_name)?.solve(self)
    }

    /// The scenario objective's value of a schedule.
    pub fn evaluate(&self, schedule: &Schedule) -> u64 {
        self.objective.evaluate(&self.jobs, &schedule.trace)
    }

    /// Re-check invariants (builder-validated; solvers call this so even
    /// hand-mutated scenarios fail loudly with typed errors).
    pub fn validate(&self) -> Result<()> {
        self.topology.validate()?;
        self.params.validate()?;
        if let Some(a) = &self.arrival {
            a.validate()?;
        }
        if let Objective::DeadlineMiss { deadlines }
        | Objective::WeightedTardiness { deadlines } = &self.objective
        {
            if deadlines.is_empty() {
                return Err(Error::Config(format!(
                    "{} objective needs at least one deadline",
                    self.objective.key()
                )));
            }
        }
        Ok(())
    }

    /// Load from a TOML file holding a `[scenario]` section (or the
    /// scenario fields at top level).
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Scenario> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text (see [`Scenario::load`]).
    pub fn from_toml(text: &str) -> Result<Scenario> {
        let v = crate::serialize::toml::parse(text)?;
        let root = FieldReader::new(&v, "scenario")?;
        let scenario = match root.section("scenario")? {
            Some(section) => {
                let s = Scenario::from_reader(&section)?;
                root.finish()?;
                s
            }
            None => Scenario::from_reader(&root)?,
        };
        Ok(scenario)
    }

    /// Parse a `[scenario]` section, layered over paper defaults.
    pub fn from_reader(r: &FieldReader) -> Result<Scenario> {
        let mut b = Scenario::builder();
        if let Some(name) = r.string("name")? {
            b = b.name(name);
        }
        if let Some(seed) = r.u64("seed")? {
            b = b.seed(seed);
        }
        // arrival process + its sizing fields (only the fields of the
        // selected process are meaningful; others are rejected as
        // unknown by `finish`) — shared with `[[metro.ward]]` sections
        let arrival = Arrival::from_reader(r)?;
        b = b.arrival(arrival);
        // objective (+ deadlines, only meaningful for the
        // deadline-carrying objectives)
        let deadlines = r.u64_list("deadlines")?.unwrap_or_default();
        match r.string("objective")? {
            Some(obj) => {
                let parsed = Objective::parse(&obj, &deadlines)?;
                if !deadlines.is_empty()
                    && !matches!(
                        parsed,
                        Objective::DeadlineMiss { .. }
                            | Objective::WeightedTardiness { .. }
                    )
                {
                    return Err(Error::Config(
                        "scenario.deadlines is only meaningful with \
                         `objective = \"deadline-miss\"` or \
                         `objective = \"weighted-tardiness\"`"
                            .into(),
                    ));
                }
                b = b.objective(parsed);
            }
            None if !deadlines.is_empty() => {
                return Err(Error::Config(
                    "scenario.deadlines is only meaningful with \
                     `objective = \"deadline-miss\"` or \
                     `objective = \"weighted-tardiness\"`"
                        .into(),
                ));
            }
            None => {}
        }
        if let Some(t) = r.section("topology")? {
            b = b.topology(Topology::from_reader(&t)?);
        }
        if let Some(p) = r.section("scheduler")? {
            b = b.params(SchedulerParams::from_reader(&p)?);
        }
        r.finish()?;
        b.build()
    }

    /// Serialize the scenario *spec* as a config section (inverse of
    /// [`Scenario::from_reader`] for arrival-generated scenarios;
    /// literal job lists are not expressible in TOML and are omitted —
    /// such a scenario round-trips as the paper trace).
    pub fn to_value(&self) -> Value {
        let mut v = Value::object();
        v.set("name", self.name.as_str());
        v.set("seed", self.seed);
        self.arrival
            .clone()
            .unwrap_or_default()
            .write_fields(&mut v);
        v.set("objective", self.objective.key());
        if let Objective::DeadlineMiss { deadlines }
        | Objective::WeightedTardiness { deadlines } = &self.objective
        {
            v.set(
                "deadlines",
                Value::Array(
                    deadlines.iter().map(|&d| Value::from(d)).collect(),
                ),
            );
        }
        v.set("topology", self.topology.to_value());
        v.set("scheduler", self.params.to_value());
        v
    }

    /// One-line description for reports.
    pub fn label(&self) -> String {
        format!(
            "{} ({} jobs, {}, objective {})",
            self.name,
            self.jobs.len(),
            self.topology.label(),
            self.objective.key()
        )
    }
}

/// Builder for [`Scenario`] — the only construction path that validates.
#[derive(Debug, Clone, Default)]
pub struct ScenarioBuilder {
    name: Option<String>,
    jobs: Option<Vec<Job>>,
    arrival: Option<Arrival>,
    seed: u64,
    topology: Topology,
    objective: Objective,
    params: SchedulerParams,
}

impl ScenarioBuilder {
    /// Display name.
    pub fn name(mut self, name: impl Into<String>) -> Self {
        self.name = Some(name.into());
        self
    }

    /// A literal job list (mutually exclusive with [`Self::arrival`]).
    pub fn jobs(mut self, jobs: Vec<Job>) -> Self {
        self.jobs = Some(jobs);
        self
    }

    /// A generative arrival process (mutually exclusive with
    /// [`Self::jobs`]); realized with the builder seed at
    /// [`Self::build`] time.
    pub fn arrival(mut self, arrival: Arrival) -> Self {
        self.arrival = Some(arrival);
        self
    }

    /// Deterministic seed for the arrival process (default 0): the same
    /// `(arrival, seed)` pair always realizes the same job list.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// The machine set (default: the paper's 1-cloud + 1-edge).
    pub fn topology(mut self, topology: Topology) -> Self {
        self.topology = topology;
        self
    }

    /// The objective solvers minimize (default: eq. 5).
    pub fn objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    /// Algorithm 2 tunables for the tabu solver.
    pub fn params(mut self, params: SchedulerParams) -> Self {
        self.params = params;
        self
    }

    /// Validate and realize the scenario (generates jobs from the
    /// arrival process if one was given).
    pub fn build(self) -> Result<Scenario> {
        self.topology.validate()?;
        if self.jobs.is_some() && self.arrival.is_some() {
            return Err(Error::Config(
                "scenario: provide either a literal job list or an \
                 arrival process, not both"
                    .into(),
            ));
        }
        let (jobs, arrival) = match (self.jobs, self.arrival) {
            (Some(jobs), None) => (jobs, None),
            (None, arrival) => {
                let a = arrival.unwrap_or_default();
                a.validate()?;
                (a.generate(self.seed), Some(a))
            }
            (Some(_), Some(_)) => unreachable!("rejected above"),
        };
        let name = self.name.unwrap_or_else(|| {
            arrival
                .as_ref()
                .map(|a| a.key().to_string())
                .unwrap_or_else(|| "custom".to_string())
        });
        let scenario = Scenario {
            name,
            jobs,
            arrival,
            seed: self.seed,
            topology: self.topology,
            objective: self.objective,
            params: self.params,
        };
        scenario.validate()?;
        Ok(scenario)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::paper_jobs;

    #[test]
    fn paper_scenario_is_the_paper_experiment() {
        let s = Scenario::paper();
        assert_eq!(s.jobs, paper_jobs());
        assert!(s.topology.is_paper());
        assert_eq!(s.objective, Objective::WeightedSum);
        assert_eq!(s.arrival, Some(Arrival::PaperTrace));
    }

    #[test]
    fn builder_rejects_jobs_and_arrival_together() {
        let err = Scenario::builder()
            .jobs(paper_jobs())
            .arrival(Arrival::poisson_ward())
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("not both"), "{err}");
    }

    #[test]
    fn builder_rejects_invalid_topology_with_typed_error() {
        let err = Scenario::builder()
            .topology(Topology::new(1, 0))
            .build()
            .unwrap_err();
        assert!(
            matches!(err, Error::InvalidTopology { .. }),
            "{err:?}"
        );
    }

    #[test]
    fn hand_mutated_params_fail_loudly_in_solvers() {
        let mut s = Scenario::paper();
        s.params.max_iters = 0;
        assert!(s.validate().is_err());
        assert!(s.solve("tabu").is_err());
    }

    #[test]
    fn builder_rejects_empty_deadlines() {
        let err = Scenario::builder()
            .objective(Objective::DeadlineMiss { deadlines: vec![] })
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("deadline"), "{err}");
    }

    #[test]
    fn generated_scenarios_are_seed_reproducible() {
        let build = |seed| {
            Scenario::builder()
                .arrival(Arrival::poisson_ward())
                .seed(seed)
                .build()
                .unwrap()
        };
        assert_eq!(build(5).jobs, build(5).jobs);
        assert_ne!(build(5).jobs, build(6).jobs);
    }

    #[test]
    fn toml_scenario_roundtrip() {
        let text = "\
[scenario]
name = \"icu-b\"
arrival = \"poisson-ward\"
jobs = 9
rate = 0.5
seed = 11
objective = \"deadline-miss\"
deadlines = [25, 40]

[scenario.topology]
clouds = 1
edges = 2
";
        let s = Scenario::from_toml(text).unwrap();
        assert_eq!(s.name, "icu-b");
        assert_eq!(s.jobs.len(), 9);
        assert_eq!(s.seed, 11);
        assert_eq!(s.topology, Topology::new(1, 2));
        assert_eq!(
            s.objective,
            Objective::DeadlineMiss { deadlines: vec![25, 40] }
        );
        // spec serialization re-parses to the same scenario
        let mut root = Value::object();
        root.set("scenario", s.to_value());
        let text2 =
            crate::serialize::toml::emit(&root);
        let back = Scenario::from_toml(&text2).unwrap();
        assert_eq!(back, s, "emitted:\n{text2}");
    }

    #[test]
    fn toml_heterogeneous_topology_roundtrip() {
        let text = "\
[scenario]
name = \"biglittle\"
arrival = \"poisson-ward\"
jobs = 6
rate = 0.4
seed = 3

[scenario.topology]
clouds = 1
edges = 2
edge_speeds = [1.5, 0.75]
";
        let s = Scenario::from_toml(text).unwrap();
        assert_eq!(
            s.topology,
            Topology::with_speeds(
                1,
                2,
                None,
                Some(vec![1.5, 0.75])
            )
            .unwrap()
        );
        assert_eq!(
            s.topology.speed(crate::topology::MachineRef::edge(1)),
            0.75
        );
        // spec serialization re-parses to the same scenario, speeds
        // included
        let mut root = Value::object();
        root.set("scenario", s.to_value());
        let text2 = crate::serialize::toml::emit(&root);
        let back = Scenario::from_toml(&text2).unwrap();
        assert_eq!(back, s, "emitted:\n{text2}");
        // invalid speed vectors are typed topology errors
        let bad = "\
[scenario]

[scenario.topology]
edges = 2
edge_speeds = [1.5, 0.0]
";
        assert!(matches!(
            Scenario::from_toml(bad),
            Err(Error::InvalidTopology { .. })
        ));
    }

    #[test]
    fn toml_link_topology_roundtrip() {
        let text = "\
[scenario]
name = \"wifi-wired\"
arrival = \"poisson-ward\"
jobs = 6
rate = 0.4
seed = 3

[scenario.topology]
clouds = 1
edges = 2
edge_links = [0.5, 1.0]
";
        let s = Scenario::from_toml(text).unwrap();
        assert_eq!(
            s.topology,
            Topology::with_links(1, 2, None, Some(vec![0.5, 1.0]))
                .unwrap()
        );
        assert_eq!(
            s.topology.link(crate::topology::MachineRef::edge(0)),
            0.5
        );
        assert_eq!(
            s.topology.speed(crate::topology::MachineRef::edge(0)),
            1.0
        );
        // spec serialization re-parses to the same scenario, links
        // included
        let mut root = Value::object();
        root.set("scenario", s.to_value());
        let text2 = crate::serialize::toml::emit(&root);
        let back = Scenario::from_toml(&text2).unwrap();
        assert_eq!(back, s, "emitted:\n{text2}");
        // both axes at once round-trip too
        let both = Scenario::builder()
            .topology(
                Topology::with_factors(
                    2,
                    1,
                    Some(vec![2.0, 1.0]),
                    None,
                    Some(vec![0.5, 2.0]),
                    None,
                )
                .unwrap(),
            )
            .build()
            .unwrap();
        let mut root = Value::object();
        root.set("scenario", both.to_value());
        let back2 =
            Scenario::from_toml(&crate::serialize::toml::emit(&root))
                .unwrap();
        assert_eq!(back2.topology, both.topology);
        // invalid link vectors are typed topology errors
        let bad = "\
[scenario]

[scenario.topology]
edges = 2
edge_links = [1.5, 0.0]
";
        assert!(matches!(
            Scenario::from_toml(bad),
            Err(Error::InvalidTopology { .. })
        ));
    }

    #[test]
    fn toml_diurnal_ward_roundtrip() {
        let text = "\
[scenario]
arrival = \"diurnal-ward\"
jobs = 8
rate = 0.3
amplitude = 0.6
period = 36
seed = 4
";
        let s = Scenario::from_toml(text).unwrap();
        assert_eq!(s.jobs.len(), 8);
        assert_eq!(
            s.arrival,
            Some(Arrival::DiurnalWard {
                jobs: 8,
                rate: 0.3,
                amplitude: 0.6,
                period: 36,
            })
        );
        let mut root = Value::object();
        root.set("scenario", s.to_value());
        let back =
            Scenario::from_toml(&crate::serialize::toml::emit(&root))
                .unwrap();
        assert_eq!(back, s);
        // diurnal sizing fields stay unknown on the other processes
        assert!(Scenario::from_toml(
            "[scenario]\narrival = \"poisson-ward\"\namplitude = 0.5\n"
        )
        .is_err());
    }

    #[test]
    fn toml_correlated_burst_roundtrip() {
        let text = "\
[scenario]
arrival = \"correlated-burst\"
events = 5
rate = 0.2
burst = 2
span = 3
seed = 9
";
        let s = Scenario::from_toml(text).unwrap();
        assert_eq!(s.jobs.len(), 10, "events * burst jobs");
        assert_eq!(
            s.arrival,
            Some(Arrival::CorrelatedBurst {
                events: 5,
                rate: 0.2,
                burst: 2,
                span: 3,
            })
        );
        let mut root = Value::object();
        root.set("scenario", s.to_value());
        let back =
            Scenario::from_toml(&crate::serialize::toml::emit(&root))
                .unwrap();
        assert_eq!(back, s);
        // burst sizing fields stay unknown on the other processes
        assert!(Scenario::from_toml(
            "[scenario]\narrival = \"poisson-ward\"\nburst = 2\n"
        )
        .is_err());
    }

    #[test]
    fn toml_weighted_tardiness_roundtrip() {
        let text = "\
[scenario]
arrival = \"poisson-ward\"
jobs = 6
rate = 0.4
seed = 2
objective = \"weighted-tardiness\"
deadlines = [30, 45]
";
        let s = Scenario::from_toml(text).unwrap();
        assert_eq!(
            s.objective,
            Objective::WeightedTardiness { deadlines: vec![30, 45] }
        );
        let mut root = Value::object();
        root.set("scenario", s.to_value());
        let back =
            Scenario::from_toml(&crate::serialize::toml::emit(&root))
                .unwrap();
        assert_eq!(back, s);
        // weighted-tardiness without deadlines is rejected
        assert!(Scenario::from_toml(
            "[scenario]\nobjective = \"weighted-tardiness\"\n"
        )
        .is_err());
    }

    #[test]
    fn toml_without_section_header_also_parses() {
        let s = Scenario::from_toml(
            "arrival = \"code-blue-surge\"\nsurge = 3\n",
        )
        .unwrap();
        assert_eq!(s.name, "code-blue-surge");
        match s.arrival {
            Some(Arrival::CodeBlueSurge { surge, .. }) => {
                assert_eq!(surge, 3)
            }
            other => panic!("wrong arrival: {other:?}"),
        }
    }

    #[test]
    fn toml_unknown_fields_rejected() {
        assert!(Scenario::from_toml("[scenario]\nbanana = 1\n").is_err());
        // sizing fields of the *other* process are unknown too
        assert!(Scenario::from_toml(
            "[scenario]\narrival = \"paper-trace\"\nrate = 0.5\n"
        )
        .is_err());
        // deadlines without the deadline-miss objective are rejected,
        // whether the objective is implicit or explicit
        assert!(Scenario::from_toml(
            "[scenario]\ndeadlines = [5]\n"
        )
        .is_err());
        assert!(Scenario::from_toml(
            "[scenario]\nobjective = \"makespan\"\ndeadlines = [5]\n"
        )
        .is_err());
    }

    #[test]
    fn solve_through_the_registry() {
        let s = Scenario::paper();
        let tabu = s.solve("tabu").unwrap();
        let edge = s.solve("all-edge").unwrap();
        assert!(s.evaluate(&tabu) <= s.evaluate(&edge));
        assert!(s.solve("nope").is_err());
    }

    #[test]
    fn label_mentions_the_essentials() {
        let l = Scenario::paper().label();
        assert!(l.contains("paper"), "{l}");
        assert!(l.contains("10 jobs"), "{l}");
        assert!(l.contains("weighted-sum"), "{l}");
    }
}
