//! Generative job-arrival processes.
//!
//! A [`Scenario`](super::Scenario) holds either a literal job list or an
//! [`Arrival`] process realized from one deterministic seed.  Generated
//! jobs are sampled from the paper's Table VI rows (the calibrated
//! cost profile of the three ICU applications) with ±25% jitter, so
//! synthetic wards stay in the paper's cost regime while release times
//! follow the selected process:
//!
//! * [`Arrival::PaperTrace`] — the 10-job Table VI trace, verbatim.
//! * [`Arrival::PoissonWard`] — a steady ward: exponential interarrivals
//!   at `rate` jobs per tick.
//! * [`Arrival::CodeBlueSurge`] — the same steady ward plus a burst of
//!   emergency-priority jobs released nearly simultaneously at
//!   `surge_at` (a code-blue event: every monitor in the room fires).
//! * [`Arrival::DiurnalWard`] — a time-varying Poisson ward following a
//!   day/night rhythm: the instantaneous rate swings around `rate` by
//!   ±`amplitude` along a `period`-long piecewise-linear wave, realized
//!   by Lewis–Shedler thinning.  The waveform is a triangle rather than
//!   a sinusoid on purpose: the modulation itself is exact IEEE-754
//!   arithmetic, adding no libm dependence beyond the `log` already
//!   inside every ward's exponential sampler ([`Rng::exponential`]).
//! * [`Arrival::CorrelatedBurst`] — patient-correlated bursts: parent
//!   events arrive as a Poisson process at `rate`, and each one spawns a
//!   clustered batch of `burst` jobs across app classes, released within
//!   `span` ticks of the parent (one deteriorating patient fires several
//!   monitors at once — arrivals are correlated, not independent).
//!
//! Generation is a pure function of `(process, seed)` — the same seed
//! reproduces the same job list bit-for-bit on a given platform, which
//! the registry tests, benches, and the [`crate::suite`] golden
//! baselines rely on.  (Cross-platform, the single remaining
//! platform-defined operation is libm's `log`; everything else is exact
//! integer or IEEE-754 arithmetic.)

use crate::data::Rng;
use crate::scheduler::{paper_jobs, Job};
use crate::simulation::Tick;
use crate::{Error, Result};

/// How a scenario's jobs come to exist.
#[derive(Debug, Clone, PartialEq)]
pub enum Arrival {
    /// The paper's 10-job Table VI trace (seed-independent).
    PaperTrace,
    /// `jobs` arrivals with exponential interarrival times at `rate`
    /// jobs per tick, each job sampled from the Table VI catalog.
    PoissonWard { jobs: usize, rate: f64 },
    /// A Poisson baseline of `baseline` jobs at `rate`, plus `surge`
    /// emergency (weight-2) jobs released within a few ticks of
    /// `surge_at`.
    CodeBlueSurge {
        baseline: usize,
        rate: f64,
        surge: usize,
        surge_at: Tick,
    },
    /// `jobs` arrivals from a time-varying Poisson process whose
    /// instantaneous rate follows a day/night rhythm: a triangle wave of
    /// the given `period` (ticks per full day) swinging the mean `rate`
    /// by ±`amplitude` (0 = steady ward, 1 = the ward empties at night).
    DiurnalWard {
        jobs: usize,
        rate: f64,
        amplitude: f64,
        period: Tick,
    },
    /// `events` Poisson parent events at `rate`, each spawning `burst`
    /// catalog-sampled jobs released within `span` ticks of the parent —
    /// `events * burst` jobs total, clustered rather than independent.
    CorrelatedBurst {
        events: usize,
        rate: f64,
        burst: usize,
        span: Tick,
    },
}

impl Default for Arrival {
    fn default() -> Self {
        Arrival::PaperTrace
    }
}

impl Arrival {
    /// Canonical CLI/TOML key.
    pub fn key(&self) -> &'static str {
        match self {
            Arrival::PaperTrace => "paper-trace",
            Arrival::PoissonWard { .. } => "poisson-ward",
            Arrival::CodeBlueSurge { .. } => "code-blue-surge",
            Arrival::DiurnalWard { .. } => "diurnal-ward",
            Arrival::CorrelatedBurst { .. } => "correlated-burst",
        }
    }

    /// Every arrival process with its default CLI sizing, in key order
    /// (what `Arrival::parse` accepts; suite/docs enumeration).
    pub fn defaults() -> [Arrival; 5] {
        [
            Arrival::PaperTrace,
            Arrival::poisson_ward(),
            Arrival::code_blue_surge(),
            Arrival::diurnal_ward(),
            Arrival::correlated_burst(),
        ]
    }

    /// A Poisson ward with the default CLI sizing.
    pub fn poisson_ward() -> Arrival {
        Arrival::PoissonWard { jobs: 12, rate: 0.25 }
    }

    /// A code-blue surge with the default CLI sizing.
    pub fn code_blue_surge() -> Arrival {
        Arrival::CodeBlueSurge {
            baseline: 8,
            rate: 0.2,
            surge: 5,
            surge_at: 30,
        }
    }

    /// A diurnal ward with the default CLI sizing: a two-shift day of 48
    /// ticks, load swinging ±80% around the mean rate.
    pub fn diurnal_ward() -> Arrival {
        Arrival::DiurnalWard {
            jobs: 12,
            rate: 0.25,
            amplitude: 0.8,
            period: 48,
        }
    }

    /// A correlated-burst ward with the default CLI sizing: 4 parent
    /// events spawning 3-job clusters within 4 ticks (12 jobs).
    pub fn correlated_burst() -> Arrival {
        Arrival::CorrelatedBurst {
            events: 4,
            rate: 0.1,
            burst: 3,
            span: 4,
        }
    }

    /// Read the `arrival` key plus the selected process's sizing fields
    /// from a config section (shared by `[scenario]` and
    /// `[[metro.ward]]` parsing).  Only the fields of the selected
    /// process are consumed; foreign sizing fields are left for the
    /// caller's `finish()` to reject as unknown.
    pub fn from_reader(
        r: &crate::config::FieldReader,
    ) -> Result<Arrival> {
        let mut arrival = match r.string("arrival")? {
            Some(kind) => Arrival::parse(&kind)?,
            None => Arrival::PaperTrace,
        };
        match &mut arrival {
            Arrival::PaperTrace => {}
            Arrival::PoissonWard { jobs, rate } => {
                if let Some(n) = r.usize("jobs")? {
                    *jobs = n;
                }
                if let Some(x) = r.f64("rate")? {
                    *rate = x;
                }
            }
            Arrival::CodeBlueSurge {
                baseline,
                rate,
                surge,
                surge_at,
            } => {
                if let Some(n) = r.usize("baseline")? {
                    *baseline = n;
                }
                if let Some(x) = r.f64("rate")? {
                    *rate = x;
                }
                if let Some(n) = r.usize("surge")? {
                    *surge = n;
                }
                if let Some(t) = r.u64("surge_at")? {
                    *surge_at = t;
                }
            }
            Arrival::DiurnalWard {
                jobs,
                rate,
                amplitude,
                period,
            } => {
                if let Some(n) = r.usize("jobs")? {
                    *jobs = n;
                }
                if let Some(x) = r.f64("rate")? {
                    *rate = x;
                }
                if let Some(x) = r.f64("amplitude")? {
                    *amplitude = x;
                }
                if let Some(p) = r.u64("period")? {
                    *period = p;
                }
            }
            Arrival::CorrelatedBurst {
                events,
                rate,
                burst,
                span,
            } => {
                if let Some(n) = r.usize("events")? {
                    *events = n;
                }
                if let Some(x) = r.f64("rate")? {
                    *rate = x;
                }
                if let Some(n) = r.usize("burst")? {
                    *burst = n;
                }
                if let Some(t) = r.u64("span")? {
                    *span = t;
                }
            }
        }
        Ok(arrival)
    }

    /// Write the `arrival` key and the process's sizing fields into a
    /// config object (inverse of [`Arrival::from_reader`]; shared by the
    /// scenario and metro-ward spec serializers).
    pub fn write_fields(&self, v: &mut crate::serialize::Value) {
        v.set("arrival", self.key());
        match *self {
            Arrival::PaperTrace => {}
            Arrival::PoissonWard { jobs, rate } => {
                v.set("jobs", jobs);
                v.set("rate", rate);
            }
            Arrival::CodeBlueSurge {
                baseline,
                rate,
                surge,
                surge_at,
            } => {
                v.set("baseline", baseline);
                v.set("rate", rate);
                v.set("surge", surge);
                v.set("surge_at", surge_at);
            }
            Arrival::DiurnalWard {
                jobs,
                rate,
                amplitude,
                period,
            } => {
                v.set("jobs", jobs);
                v.set("rate", rate);
                v.set("amplitude", amplitude);
                v.set("period", period);
            }
            Arrival::CorrelatedBurst {
                events,
                rate,
                burst,
                span,
            } => {
                v.set("events", events);
                v.set("rate", rate);
                v.set("burst", burst);
                v.set("span", span);
            }
        }
    }

    /// Parse a CLI/TOML arrival key into the default-sized process (the
    /// scenario spec then overrides individual fields).
    pub fn parse(name: &str) -> Result<Arrival> {
        match name.to_ascii_lowercase().replace('_', "-").as_str() {
            "paper-trace" | "paper" | "table-vi" => {
                Ok(Arrival::PaperTrace)
            }
            "poisson-ward" | "poisson" | "ward" => {
                Ok(Arrival::poisson_ward())
            }
            "code-blue-surge" | "code-blue" | "surge" => {
                Ok(Arrival::code_blue_surge())
            }
            "diurnal-ward" | "diurnal" => Ok(Arrival::diurnal_ward()),
            "correlated-burst" | "correlated" | "burst" => {
                Ok(Arrival::correlated_burst())
            }
            other => Err(Error::Config(format!(
                "unknown arrival process {other:?}; expected paper-trace \
                 | poisson-ward | code-blue-surge | diurnal-ward | \
                 correlated-burst"
            ))),
        }
    }

    /// Apply generic sizing overrides (the CLI's `--jobs/--rate/--surge/
    /// --surge-at` flags): `count` sets `jobs` (PoissonWard) or
    /// `baseline` (CodeBlueSurge).  Errors loudly instead of silently
    /// ignoring a flag the selected process has no use for.
    pub fn override_sizing(
        &mut self,
        count: Option<usize>,
        rate: Option<f64>,
        surge: Option<usize>,
        surge_at: Option<Tick>,
    ) -> Result<()> {
        match self {
            Arrival::PaperTrace => {
                if count.is_some()
                    || rate.is_some()
                    || surge.is_some()
                    || surge_at.is_some()
                {
                    return Err(Error::Config(
                        "sizing options (--jobs/--rate/--surge/\
                         --surge-at) need a generative arrival process \
                         (poisson-ward | code-blue-surge); the paper \
                         trace is fixed"
                            .into(),
                    ));
                }
            }
            Arrival::PoissonWard { jobs, rate: r } => {
                if surge.is_some() || surge_at.is_some() {
                    return Err(Error::Config(
                        "--surge/--surge-at only apply to the \
                         code-blue-surge arrival process"
                            .into(),
                    ));
                }
                if let Some(n) = count {
                    *jobs = n;
                }
                if let Some(x) = rate {
                    *r = x;
                }
            }
            Arrival::CodeBlueSurge {
                baseline,
                rate: r,
                surge: s,
                surge_at: t,
            } => {
                if let Some(n) = count {
                    *baseline = n;
                }
                if let Some(x) = rate {
                    *r = x;
                }
                if let Some(n) = surge {
                    *s = n;
                }
                if let Some(x) = surge_at {
                    *t = x;
                }
            }
            Arrival::DiurnalWard { jobs, rate: r, .. } => {
                if surge.is_some() || surge_at.is_some() {
                    return Err(Error::Config(
                        "--surge/--surge-at only apply to the \
                         code-blue-surge arrival process"
                            .into(),
                    ));
                }
                if let Some(n) = count {
                    *jobs = n;
                }
                if let Some(x) = rate {
                    *r = x;
                }
            }
            Arrival::CorrelatedBurst { events, rate: r, .. } => {
                if surge.is_some() || surge_at.is_some() {
                    return Err(Error::Config(
                        "--surge/--surge-at only apply to the \
                         code-blue-surge arrival process"
                            .into(),
                    ));
                }
                // --jobs sizes the parent-event count (each spawns a
                // whole burst)
                if let Some(n) = count {
                    *events = n;
                }
                if let Some(x) = rate {
                    *r = x;
                }
            }
        }
        Ok(())
    }

    /// Reject degenerate process parameters before generation.
    pub fn validate(&self) -> Result<()> {
        let rate = match self {
            Arrival::PaperTrace => return Ok(()),
            Arrival::PoissonWard { rate, .. } => *rate,
            Arrival::CodeBlueSurge { rate, .. } => *rate,
            Arrival::DiurnalWard {
                rate,
                amplitude,
                period,
                ..
            } => {
                if !(0.0..=1.0).contains(amplitude) {
                    return Err(Error::Config(format!(
                        "diurnal amplitude must be within [0, 1] (the \
                         rate cannot go negative), got {amplitude}"
                    )));
                }
                if *period == 0 {
                    return Err(Error::Config(
                        "diurnal period must be at least one tick".into(),
                    ));
                }
                *rate
            }
            Arrival::CorrelatedBurst {
                rate, burst, span, ..
            } => {
                if *burst == 0 {
                    return Err(Error::Config(
                        "correlated-burst needs at least one job per \
                         parent event"
                            .into(),
                    ));
                }
                if *span == 0 {
                    return Err(Error::Config(
                        "correlated-burst span must be at least one tick"
                            .into(),
                    ));
                }
                *rate
            }
        };
        if !(rate > 0.0) || !rate.is_finite() {
            return Err(Error::Config(format!(
                "arrival rate must be a positive finite number of jobs \
                 per tick, got {rate}"
            )));
        }
        Ok(())
    }

    /// Realize the process into a concrete job list — deterministic in
    /// `seed`.
    pub fn generate(&self, seed: u64) -> Vec<Job> {
        match *self {
            Arrival::PaperTrace => paper_jobs(),
            Arrival::PoissonWard { jobs, rate } => {
                let mut rng = Rng::new(seed ^ 0x5CE9_A210);
                poisson_stream(&mut rng, jobs, rate, 1)
            }
            Arrival::CodeBlueSurge {
                baseline,
                rate,
                surge,
                surge_at,
            } => {
                let mut rng = Rng::new(seed ^ 0xC0DE_B10E);
                let mut jobs = poisson_stream(&mut rng, baseline, rate, 1);
                let emergencies: Vec<Job> = paper_jobs()
                    .into_iter()
                    .filter(|j| j.weight >= 2)
                    .collect();
                for _ in 0..surge {
                    let template = emergencies
                        [rng.below(emergencies.len() as u64) as usize];
                    let mut j = jitter(&mut rng, template);
                    // the whole room fires within a couple of ticks
                    j.release = surge_at + rng.below(3);
                    j.weight = 2;
                    jobs.push(j);
                }
                jobs
            }
            Arrival::DiurnalWard {
                jobs,
                rate,
                amplitude,
                period,
            } => {
                let mut rng = Rng::new(seed ^ 0xD1A5_0C0D);
                let catalog = paper_jobs();
                // Lewis–Shedler thinning: candidates at the peak rate,
                // accepted with probability rate(t)/peak
                let peak = rate * (1.0 + amplitude);
                let mut out = Vec::with_capacity(jobs);
                let mut t = 1.0_f64;
                while out.len() < jobs {
                    t += rng.exponential(peak);
                    let lambda_t =
                        rate * diurnal_factor(t, period as f64, amplitude);
                    if rng.uniform() * peak <= lambda_t {
                        out.push(sample_job_at(&mut rng, &catalog, t));
                    }
                }
                out
            }
            Arrival::CorrelatedBurst {
                events,
                rate,
                burst,
                span,
            } => {
                let mut rng = Rng::new(seed ^ 0xC011_E1A7);
                let catalog = paper_jobs();
                let mut out = Vec::with_capacity(events * burst);
                let mut t = 1.0_f64;
                for _ in 0..events {
                    t += rng.exponential(rate);
                    // analysis: allow(lossy-tick-cast, "arrival clocks stay far below 2^53; ceil+max(1) keeps C3's positive integer ticks")
                    let parent = (t.ceil() as Tick).max(1);
                    for _ in 0..burst {
                        // same two-stage catalog draw every ward
                        // shares, then the release snaps into the
                        // parent's cluster window
                        let mut j = sample_job_at(&mut rng, &catalog, t);
                        j.release = parent + rng.below(span);
                        out.push(j);
                    }
                }
                out
            }
        }
    }
}

/// Piecewise-linear day/night modulation factor in
/// `[1 - amplitude, 1 + amplitude]`: a `period`-periodic triangle wave
/// bottoming out at the start of each day and peaking mid-period.  Pure
/// exact arithmetic — the waveform adds no libm dependence of its own.
fn diurnal_factor(t: f64, period: f64, amplitude: f64) -> f64 {
    let x = (t / period).fract(); // position within the day, [0, 1)
    let tri = if x < 0.5 { 4.0 * x - 1.0 } else { 3.0 - 4.0 * x };
    1.0 + amplitude * tri
}

impl std::fmt::Display for Arrival {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Arrival::PaperTrace => f.write_str("paper-trace"),
            Arrival::PoissonWard { jobs, rate } => {
                write!(f, "poisson-ward(jobs={jobs}, rate={rate})")
            }
            Arrival::CodeBlueSurge {
                baseline,
                rate,
                surge,
                surge_at,
            } => write!(
                f,
                "code-blue-surge(baseline={baseline}, rate={rate}, \
                 surge={surge} @ t={surge_at})"
            ),
            Arrival::DiurnalWard {
                jobs,
                rate,
                amplitude,
                period,
            } => write!(
                f,
                "diurnal-ward(jobs={jobs}, rate={rate}, \
                 amplitude={amplitude}, period={period})"
            ),
            Arrival::CorrelatedBurst {
                events,
                rate,
                burst,
                span,
            } => write!(
                f,
                "correlated-burst(events={events}, rate={rate}, \
                 burst={burst}, span={span})"
            ),
        }
    }
}

/// Poisson arrivals of Table-VI-like jobs starting at `t0`.
fn poisson_stream(
    rng: &mut Rng,
    n: usize,
    rate: f64,
    t0: Tick,
) -> Vec<Job> {
    let catalog = paper_jobs();
    let mut t = t0 as f64;
    (0..n)
        .map(|_| {
            t += rng.exponential(rate);
            sample_job_at(rng, &catalog, t)
        })
        .collect()
}

/// Draw one catalog job (template pick, then jitter — two RNG stages
/// every generative ward shares) released at the ceiling of time `t`.
fn sample_job_at(rng: &mut Rng, catalog: &[Job], t: f64) -> Job {
    let template = catalog[rng.below(catalog.len() as u64) as usize];
    let mut j = jitter(rng, template);
    // C3: releases are positive integer ticks (the floor only engages
    // for t < 1, which no current process produces)
    // analysis: allow(lossy-tick-cast, "arrival clocks stay far below 2^53; ceil+max(1) keeps C3's positive integer ticks")
    j.release = (t.ceil() as Tick).max(1);
    j
}

/// Jitter every cost of a catalog row by ±25% (integer ticks, floor 1 —
/// constraint C3 keeps all times non-zero integers).
fn jitter(rng: &mut Rng, template: Job) -> Job {
    let mut scale = |v: Tick| -> Tick {
        // analysis: allow(lossy-tick-cast, "catalog costs are tiny (< 100 ticks); 1.25x jitter cannot overflow")
        ((v as f64 * rng.range(0.75, 1.25)).round() as Tick).max(1)
    };
    Job {
        release: template.release,
        weight: template.weight,
        proc_cloud: scale(template.proc_cloud),
        trans_cloud: scale(template.trans_cloud),
        proc_edge: scale(template.proc_edge),
        trans_edge: scale(template.trans_edge),
        proc_device: scale(template.proc_device),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_trace_is_table_vi() {
        assert_eq!(Arrival::PaperTrace.generate(0), paper_jobs());
        assert_eq!(Arrival::PaperTrace.generate(7), paper_jobs());
    }

    #[test]
    fn generation_is_deterministic_in_the_seed() {
        for arrival in [
            Arrival::poisson_ward(),
            Arrival::code_blue_surge(),
            Arrival::diurnal_ward(),
            Arrival::correlated_burst(),
        ] {
            let a = arrival.generate(42);
            let b = arrival.generate(42);
            assert_eq!(a, b, "{arrival}: same seed must reproduce");
            let c = arrival.generate(43);
            assert_ne!(a, c, "{arrival}: different seed, same jobs?");
        }
    }

    #[test]
    fn diurnal_ward_shape() {
        let arrival = Arrival::DiurnalWard {
            jobs: 25,
            rate: 0.4,
            amplitude: 0.8,
            period: 48,
        };
        let jobs = arrival.generate(5);
        assert_eq!(jobs.len(), 25);
        // releases are non-decreasing, strictly positive integers (C3)
        for w in jobs.windows(2) {
            assert!(w[0].release <= w[1].release);
        }
        assert!(jobs[0].release >= 1);
        for j in &jobs {
            assert!(j.proc_cloud >= 1 && j.proc_edge >= 1);
            assert!(j.proc_device >= 1);
            assert!(j.trans_cloud >= 1 && j.trans_edge >= 1);
        }
    }

    #[test]
    fn diurnal_factor_waveform() {
        // the triangle wave bottoms at the start of a day, peaks
        // mid-period, and is period-periodic
        assert_eq!(diurnal_factor(0.0, 48.0, 0.5), 0.5);
        assert_eq!(diurnal_factor(24.0, 48.0, 0.5), 1.5);
        assert_eq!(diurnal_factor(48.0, 48.0, 0.5), 0.5);
        assert_eq!(diurnal_factor(12.0, 48.0, 0.5), 1.0);
        assert_eq!(
            diurnal_factor(7.0, 48.0, 0.8),
            diurnal_factor(7.0 + 96.0, 48.0, 0.8)
        );
        // amplitude 0 degenerates to the homogeneous ward
        for t in 0..100 {
            assert_eq!(diurnal_factor(t as f64, 48.0, 0.0), 1.0);
        }
    }

    #[test]
    fn diurnal_ward_rejects_degenerate_parameters() {
        let ok = Arrival::diurnal_ward();
        assert!(ok.validate().is_ok());
        let bad_amp = |amplitude: f64| Arrival::DiurnalWard {
            jobs: 5,
            rate: 0.3,
            amplitude,
            period: 48,
        };
        assert!(bad_amp(-0.1).validate().is_err());
        assert!(bad_amp(1.5).validate().is_err());
        assert!(bad_amp(f64::NAN).validate().is_err());
        assert!(Arrival::DiurnalWard {
            jobs: 5,
            rate: 0.3,
            amplitude: 0.5,
            period: 0,
        }
        .validate()
        .is_err());
        assert!(Arrival::DiurnalWard {
            jobs: 5,
            rate: 0.0,
            amplitude: 0.5,
            period: 48,
        }
        .validate()
        .is_err());
    }

    #[test]
    fn poisson_ward_shape() {
        let jobs =
            Arrival::PoissonWard { jobs: 30, rate: 0.5 }.generate(9);
        assert_eq!(jobs.len(), 30);
        // releases are non-decreasing and strictly positive integers
        for w in jobs.windows(2) {
            assert!(w[0].release <= w[1].release);
        }
        assert!(jobs[0].release >= 1);
        // every cost respects C3 (non-zero except device transmission)
        for j in &jobs {
            assert!(j.proc_cloud >= 1 && j.proc_edge >= 1);
            assert!(j.proc_device >= 1);
            assert!(j.trans_cloud >= 1 && j.trans_edge >= 1);
        }
    }

    #[test]
    fn code_blue_surge_injects_emergencies() {
        let arrival = Arrival::CodeBlueSurge {
            baseline: 6,
            rate: 0.2,
            surge: 4,
            surge_at: 50,
        };
        let jobs = arrival.generate(3);
        assert_eq!(jobs.len(), 10);
        let surge = jobs
            .iter()
            .filter(|j| (50..53).contains(&j.release) && j.weight == 2)
            .count();
        assert!(surge >= 4, "surge jobs missing: {jobs:?}");
    }

    #[test]
    fn correlated_burst_shape() {
        let arrival = Arrival::CorrelatedBurst {
            events: 5,
            rate: 0.1,
            burst: 4,
            span: 3,
        };
        let jobs = arrival.generate(11);
        assert_eq!(jobs.len(), 20, "events * burst jobs");
        // each consecutive chunk of 4 is one parent's cluster: all
        // releases within `span` ticks of the cluster's earliest
        for cluster in jobs.chunks(4) {
            let earliest =
                cluster.iter().map(|j| j.release).min().unwrap();
            let latest =
                cluster.iter().map(|j| j.release).max().unwrap();
            assert!(earliest >= 1);
            assert!(
                latest < earliest + 3,
                "cluster spread {earliest}..={latest} exceeds the span"
            );
            for j in cluster {
                assert!(j.proc_cloud >= 1 && j.proc_edge >= 1);
                assert!(j.proc_device >= 1);
                assert!(j.trans_cloud >= 1 && j.trans_edge >= 1);
            }
        }
    }

    #[test]
    fn correlated_burst_rejects_degenerate_parameters() {
        assert!(Arrival::correlated_burst().validate().is_ok());
        let bad = |rate: f64, burst: usize, span: Tick| {
            Arrival::CorrelatedBurst { events: 3, rate, burst, span }
        };
        assert!(bad(0.0, 3, 4).validate().is_err());
        assert!(bad(f64::NAN, 3, 4).validate().is_err());
        assert!(bad(0.1, 0, 4).validate().is_err());
        assert!(bad(0.1, 3, 0).validate().is_err());
    }

    #[test]
    fn correlated_burst_override_sizing() {
        let mut b = Arrival::correlated_burst();
        b.override_sizing(Some(7), Some(0.3), None, None).unwrap();
        match b {
            Arrival::CorrelatedBurst { events, rate, .. } => {
                assert_eq!((events, rate), (7, 0.3));
            }
            other => panic!("{other:?}"),
        }
        assert!(b.override_sizing(None, None, Some(2), None).is_err());
        assert!(b.override_sizing(None, None, None, Some(9)).is_err());
    }

    #[test]
    fn override_sizing_is_loud_about_inapplicable_flags() {
        let mut a = Arrival::PaperTrace;
        assert!(a.override_sizing(None, None, None, None).is_ok());
        assert!(a.override_sizing(Some(5), None, None, None).is_err());
        let mut p = Arrival::poisson_ward();
        assert!(p
            .override_sizing(Some(5), Some(0.5), None, None)
            .is_ok());
        assert_eq!(p, Arrival::PoissonWard { jobs: 5, rate: 0.5 });
        assert!(p.override_sizing(None, None, Some(2), None).is_err());
        let mut c = Arrival::code_blue_surge();
        c.override_sizing(Some(4), None, Some(2), Some(60)).unwrap();
        match c {
            Arrival::CodeBlueSurge {
                baseline, surge, surge_at, ..
            } => {
                assert_eq!((baseline, surge, surge_at), (4, 2, 60));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn zero_or_negative_rates_rejected() {
        assert!(Arrival::PoissonWard { jobs: 3, rate: 0.0 }
            .validate()
            .is_err());
        assert!(Arrival::PoissonWard { jobs: 3, rate: -1.0 }
            .validate()
            .is_err());
        assert!(Arrival::PoissonWard { jobs: 3, rate: f64::NAN }
            .validate()
            .is_err());
        assert!(Arrival::poisson_ward().validate().is_ok());
        assert!(Arrival::PaperTrace.validate().is_ok());
    }

    #[test]
    fn parse_keys() {
        assert_eq!(
            Arrival::parse("paper").unwrap(),
            Arrival::PaperTrace
        );
        assert_eq!(
            Arrival::parse("poisson-ward").unwrap().key(),
            "poisson-ward"
        );
        assert_eq!(
            Arrival::parse("code_blue_surge").unwrap().key(),
            "code-blue-surge"
        );
        assert_eq!(
            Arrival::parse("diurnal").unwrap().key(),
            "diurnal-ward"
        );
        assert!(Arrival::parse("meteor").is_err());
    }

    #[test]
    fn parse_and_key_roundtrip_for_all_variants() {
        for arrival in Arrival::defaults() {
            let back = Arrival::parse(arrival.key())
                .unwrap_or_else(|e| panic!("{}: {e}", arrival.key()));
            assert_eq!(back, arrival, "{} did not round-trip", arrival);
        }
    }

    #[test]
    fn diurnal_override_sizing() {
        let mut d = Arrival::diurnal_ward();
        d.override_sizing(Some(20), Some(0.5), None, None).unwrap();
        match d {
            Arrival::DiurnalWard { jobs, rate, .. } => {
                assert_eq!((jobs, rate), (20, 0.5));
            }
            other => panic!("{other:?}"),
        }
        // surge flags stay exclusive to code-blue-surge
        assert!(d.override_sizing(None, None, Some(2), None).is_err());
        assert!(d.override_sizing(None, None, None, Some(9)).is_err());
    }
}
