//! The polymorphic solver front door: one trait, one string-keyed
//! registry.
//!
//! Every allocation strategy in the repo — Algorithm 2's greedy stage and
//! tabu search, the branch-and-bound optimum, the non-clairvoyant online
//! dispatcher, and the four Table VII baselines — implements [`Solver`]
//! and is discoverable through [`SOLVERS`].  The CLI, benches, and tests
//! enumerate strategies uniformly instead of hard-wiring free functions;
//! adding a strategy is one registry entry.

use crate::scheduler::{
    greedy_assignment, per_job_scaled_assignment,
    schedule_exact_objective, schedule_jobs_objective,
    schedule_lns_objective, schedule_online_objective, simulate, Schedule,
    Strategy,
};
use crate::{Error, Result};

use super::Scenario;

/// A scheduling strategy: consumes a [`Scenario`] (jobs + topology +
/// objective + tunables), produces a [`Schedule`].
pub trait Solver {
    /// Canonical registry key.
    fn name(&self) -> &'static str;

    /// Solve the scenario, optimizing (or at least respecting) its
    /// objective.
    fn solve(&self, scenario: &Scenario) -> Result<Schedule>;
}

/// One registry row.
pub struct SolverSpec {
    /// Canonical key (`edgeward solve --solver <name>`).
    pub name: &'static str,
    /// Accepted aliases (lowercase, dash-normalized).
    pub aliases: &'static [&'static str],
    /// One-line description for `--compare` tables and docs.
    pub summary: &'static str,
    /// Largest job count the batch suite ([`crate::suite`]) runs this
    /// solver at; bigger scenarios get a typed "skipped" cell instead of
    /// an open-ended run.  The exponential exact search sets one (well
    /// below [`crate::scheduler::EXACT_JOB_LIMIT`], which merely guards
    /// against pathological misuse), and so does the large-instance
    /// `lns` tier (it is the recommended solver at 10k–100k jobs, but a
    /// bound keeps suite sweeps finite).
    pub suite_limit: Option<usize>,
    build: fn() -> Box<dyn Solver>,
}

impl SolverSpec {
    /// Instantiate this registry row's solver.
    pub fn build(&self) -> Box<dyn Solver> {
        (self.build)()
    }

    /// Why the batch suite would skip this solver on `scenario`
    /// (`None` = run it).
    pub fn skip_reason(&self, scenario: &Scenario) -> Option<String> {
        match self.suite_limit {
            Some(limit) if scenario.jobs.len() > limit => Some(format!(
                "{} jobs exceed {}'s {}-job suite limit",
                scenario.jobs.len(),
                self.name,
                limit
            )),
            _ => None,
        }
    }
}

/// Every registered solver, in Table VII narration order: ours first,
/// then the reference solvers, then the fixed baselines.
pub const SOLVERS: &[SolverSpec] = &[
    SolverSpec {
        name: "tabu",
        aliases: &["ours", "algorithm-2"],
        summary: "Algorithm 2: greedy seed + tabu neighborhood search",
        suite_limit: None,
        build: || Box::new(TabuSolver),
    },
    SolverSpec {
        name: "greedy",
        aliases: &["algorithm-2-greedy"],
        summary: "Algorithm 2's greedy earliest-completion stage only",
        suite_limit: None,
        build: || Box::new(GreedySolver),
    },
    SolverSpec {
        name: "exact",
        aliases: &["optimal", "branch-and-bound"],
        summary: "branch-and-bound optimum (exponential; <= 20 jobs)",
        suite_limit: Some(10),
        build: || Box::new(ExactSolver),
    },
    SolverSpec {
        name: "online",
        aliases: &["non-clairvoyant"],
        summary: "non-clairvoyant dispatcher: commit at release time",
        suite_limit: None,
        build: || Box::new(OnlineSolver),
    },
    SolverSpec {
        name: "per-job-optimal",
        aliases: &["per-job"],
        summary: "each job on its single-job-optimal layer (Figure 8)",
        suite_limit: None,
        build: || Box::new(FixedSolver(Strategy::PerJobOptimal)),
    },
    SolverSpec {
        name: "all-cloud",
        aliases: &["cloud"],
        summary: "everything on the shared cloud servers",
        suite_limit: None,
        build: || Box::new(FixedSolver(Strategy::AllCloud)),
    },
    SolverSpec {
        name: "all-edge",
        aliases: &["edge"],
        summary: "everything on the shared edge servers",
        suite_limit: None,
        build: || Box::new(FixedSolver(Strategy::AllEdge)),
    },
    SolverSpec {
        name: "all-device",
        aliases: &["device"],
        summary: "everything on the patients' own devices",
        suite_limit: None,
        build: || Box::new(FixedSolver(Strategy::AllDevice)),
    },
    // appended after the original eight so committed suite baselines
    // keep their cell positions
    SolverSpec {
        name: "lns",
        aliases: &["large-neighborhood"],
        summary: "large-neighborhood search: destroy/repair, 100k-job tier",
        suite_limit: Some(100_000),
        build: || Box::new(LnsSolver),
    },
    SolverSpec {
        name: "per-job-optimal-scaled",
        aliases: &["per-job-scaled"],
        summary: "each job on its best replica (speed- and link-aware)",
        suite_limit: None,
        build: || Box::new(PerJobScaledSolver),
    },
];

/// Look up a registry row by canonical name or alias (case- and
/// underscore-insensitive) — the enumeration entry point for the batch
/// suite and anything else that needs [`SolverSpec`] metadata rather
/// than an instantiated solver.
pub fn solver_spec(name: &str) -> Result<&'static SolverSpec> {
    let key = name.to_ascii_lowercase().replace('_', "-");
    SOLVERS
        .iter()
        .find(|s| s.name == key || s.aliases.contains(&key.as_str()))
        .ok_or_else(|| {
            Error::Config(format!(
                "unknown solver {name:?}; registered solvers: {}",
                solver_names().join(", ")
            ))
        })
}

/// Look up a solver by canonical name or alias (case- and
/// underscore-insensitive).
pub fn solver(name: &str) -> Result<Box<dyn Solver>> {
    solver_spec(name).map(|s| s.build())
}

/// Canonical names of every registered solver, in registry order.
pub fn solver_names() -> Vec<&'static str> {
    SOLVERS.iter().map(|s| s.name).collect()
}

// ------------------------------------------------------------- solvers

/// Algorithm 2: greedy seed improved by the tabu neighborhood search,
/// minimizing the scenario objective.
struct TabuSolver;

impl Solver for TabuSolver {
    fn name(&self) -> &'static str {
        "tabu"
    }

    fn solve(&self, scenario: &Scenario) -> Result<Schedule> {
        scenario.validate()?;
        Ok(schedule_jobs_objective(
            &scenario.jobs,
            &scenario.topology,
            &scenario.params,
            &scenario.objective,
        ))
    }
}

/// Algorithm 2's first stage alone (the initial feasible solution).
struct GreedySolver;

impl Solver for GreedySolver {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn solve(&self, scenario: &Scenario) -> Result<Schedule> {
        scenario.validate()?;
        let a = greedy_assignment(&scenario.jobs, &scenario.topology);
        Ok(simulate(&scenario.jobs, &scenario.topology, &a))
    }
}

/// Branch-and-bound exact optimum under the scenario objective.
struct ExactSolver;

impl Solver for ExactSolver {
    fn name(&self) -> &'static str {
        "exact"
    }

    fn solve(&self, scenario: &Scenario) -> Result<Schedule> {
        scenario.validate()?;
        schedule_exact_objective(
            &scenario.jobs,
            &scenario.topology,
            &scenario.objective,
        )
    }
}

/// Non-clairvoyant dispatcher minimizing the scenario objective's
/// marginal cost per released job.
struct OnlineSolver;

impl Solver for OnlineSolver {
    fn name(&self) -> &'static str {
        "online"
    }

    fn solve(&self, scenario: &Scenario) -> Result<Schedule> {
        scenario.validate()?;
        Ok(schedule_online_objective(
            &scenario.jobs,
            &scenario.topology,
            &scenario.objective,
        ))
    }
}

/// Large-neighborhood search: greedy seed, then seeded destroy /
/// greedy-repair / accept-if-better rounds — the solver tier for the
/// 10k–100k-job instances where the full tabu neighborhood is too slow
/// and exact is infeasible.  The scenario seed drives the destroy
/// stream, so generated and TOML scenarios solve reproducibly.
struct LnsSolver;

impl Solver for LnsSolver {
    fn name(&self) -> &'static str {
        "lns"
    }

    fn solve(&self, scenario: &Scenario) -> Result<Schedule> {
        scenario.validate()?;
        Ok(schedule_lns_objective(
            &scenario.jobs,
            &scenario.topology,
            &scenario.objective,
            scenario.seed,
        ))
    }
}

/// The speed- and link-aware per-job-optimal baseline: each job on the
/// replica minimizing its uncontended scaled execution.
struct PerJobScaledSolver;

impl Solver for PerJobScaledSolver {
    fn name(&self) -> &'static str {
        "per-job-optimal-scaled"
    }

    fn solve(&self, scenario: &Scenario) -> Result<Schedule> {
        scenario.validate()?;
        let a = per_job_scaled_assignment(
            &scenario.jobs,
            &scenario.topology,
        );
        Ok(simulate(&scenario.jobs, &scenario.topology, &a))
    }
}

/// A fixed Table VII baseline strategy (objective-independent placement;
/// the objective still decides how the result is scored).
struct FixedSolver(Strategy);

impl Solver for FixedSolver {
    fn name(&self) -> &'static str {
        self.0.solver_key()
    }

    fn solve(&self, scenario: &Scenario) -> Result<Schedule> {
        scenario.validate()?;
        let a = self.0.assignment(&scenario.jobs, &scenario.topology);
        Ok(simulate(&scenario.jobs, &scenario.topology, &a))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_and_aliases_resolve() {
        for spec in SOLVERS {
            assert_eq!(solver(spec.name).unwrap().name(), spec.name);
            for alias in spec.aliases {
                assert_eq!(solver(alias).unwrap().name(), spec.name);
            }
        }
        // normalization: case and underscores
        assert_eq!(solver("ALL_CLOUD").unwrap().name(), "all-cloud");
        assert_eq!(solver("Ours").unwrap().name(), "tabu");
    }

    #[test]
    fn unknown_solver_lists_the_registry() {
        let err = solver("simulated-annealing").unwrap_err().to_string();
        assert!(err.contains("tabu"), "{err}");
        assert!(err.contains("all-device"), "{err}");
    }

    #[test]
    fn spec_lookup_and_suite_limits() {
        assert_eq!(solver_spec("optimal").unwrap().name, "exact");
        assert!(solver_spec("nope").is_err());
        // the exponential exact search and the bounded lns tier carry
        // suite limits; exact's skip reason names the offending count
        for spec in SOLVERS {
            assert_eq!(
                spec.suite_limit.is_some(),
                matches!(spec.name, "exact" | "lns"),
                "{}",
                spec.name
            );
        }
        let exact = solver_spec("exact").unwrap();
        let small = Scenario::paper();
        assert_eq!(exact.skip_reason(&small), None);
        // lns's 100k bound never trips on committed scenarios
        assert_eq!(
            solver_spec("lns").unwrap().skip_reason(&small),
            None
        );
        let big = Scenario::builder()
            .arrival(crate::scenario::Arrival::PoissonWard {
                jobs: 11,
                rate: 0.3,
            })
            .build()
            .unwrap();
        let reason = exact.skip_reason(&big).expect("11 > 10 must skip");
        assert!(reason.contains("11 jobs"), "{reason}");
        assert_eq!(solver_spec("tabu").unwrap().skip_reason(&big), None);
    }

    #[test]
    fn names_unique_and_every_strategy_key_registered() {
        let mut names = solver_names();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), SOLVERS.len());
        for s in Strategy::ALL {
            assert!(
                solver(s.solver_key()).is_ok(),
                "{:?} key {} not in registry",
                s,
                s.solver_key()
            );
        }
    }
}
