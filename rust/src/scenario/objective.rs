//! Pluggable scheduling objectives.
//!
//! The paper optimizes exactly one quantity — the priority-weighted whole
//! response time `Σ wᵢ(Eᵢ − Rᵢ)` (eq. 5).  The Cloud Continuum literature
//! on time-sensitive allocation frames the same machine model under
//! several other objectives (makespan, deadline satisfaction, unweighted
//! latency sums); an [`Objective`] names one of them and every solver core
//! ([`crate::scheduler`]) optimizes whichever is selected.
//!
//! All objectives are *monotone* in job completion times: delaying any
//! job never improves the value.  That single property is what makes the
//! branch-and-bound prefix pruning and the warm-start monotonicity
//! arguments valid for every variant here, so new objectives must
//! preserve it.

use crate::scheduler::Job;
use crate::simulation::{ScheduleTrace, Tick};
use crate::{Error, Result};

/// What a solver minimizes over a job set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Objective {
    /// Priority-weighted whole response time `Σ wᵢ(Eᵢ − Rᵢ)` — the
    /// paper's eq. 5, the default everywhere.
    WeightedSum,
    /// Unweighted whole response time `Σ (Eᵢ − Rᵢ)` — the number the
    /// paper's Table VII actually prints.
    UnweightedSum,
    /// Completion time of the last job `max Eᵢ`.
    Makespan,
    /// Number of jobs whose response time `Eᵢ − Rᵢ` exceeds their
    /// deadline.  `deadlines` is cycled over job indices (`i % len`), so
    /// a single entry broadcasts one deadline to every job; it must be
    /// non-empty (validated by the scenario builder).
    DeadlineMiss { deadlines: Vec<Tick> },
    /// Priority-weighted total tardiness `Σ wᵢ·max(0, (Eᵢ − Rᵢ) − dᵢ)`:
    /// a miss counts in proportion to both how *important* and how
    /// *late* the job is, where `DeadlineMiss` counts every miss as 1.
    /// `deadlines` cycles over job indices exactly like `DeadlineMiss`
    /// and must be non-empty.  Monotone: delaying a job only grows (or
    /// leaves unchanged) its clamped lateness.
    WeightedTardiness { deadlines: Vec<Tick> },
}

impl Default for Objective {
    fn default() -> Self {
        Objective::WeightedSum
    }
}

impl Objective {
    /// Canonical keys of every registered objective, in declaration
    /// order — what `edgeward suite --objectives all` sweeps over.
    pub const KEYS: [&'static str; 5] = [
        "weighted-sum",
        "unweighted-sum",
        "makespan",
        "deadline-miss",
        "weighted-tardiness",
    ];

    /// Canonical CLI/TOML key (`deadline-miss` etc.).
    pub fn key(&self) -> &'static str {
        match self {
            Objective::WeightedSum => "weighted-sum",
            Objective::UnweightedSum => "unweighted-sum",
            Objective::Makespan => "makespan",
            Objective::DeadlineMiss { .. } => "deadline-miss",
            Objective::WeightedTardiness { .. } => "weighted-tardiness",
        }
    }

    /// Human label for tables and reports.
    pub fn label(&self) -> &'static str {
        match self {
            Objective::WeightedSum => "weighted whole response (eq. 5)",
            Objective::UnweightedSum => "whole response time",
            Objective::Makespan => "makespan",
            Objective::DeadlineMiss { .. } => "deadline misses",
            Objective::WeightedTardiness { .. } => "weighted tardiness",
        }
    }

    /// Parse a CLI/TOML objective key.  `deadlines` is only consulted
    /// for the deadline-carrying objectives (`deadline-miss`,
    /// `weighted-tardiness`) and must be non-empty there.
    pub fn parse(name: &str, deadlines: &[Tick]) -> Result<Objective> {
        match name.to_ascii_lowercase().replace('_', "-").as_str() {
            "weighted-sum" | "weighted" | "eq5" => {
                Ok(Objective::WeightedSum)
            }
            "unweighted-sum" | "unweighted" | "whole-response" => {
                Ok(Objective::UnweightedSum)
            }
            "makespan" | "last-completion" => Ok(Objective::Makespan),
            "deadline-miss" | "deadline" | "misses" => {
                if deadlines.is_empty() {
                    return Err(Error::Config(
                        "objective deadline-miss needs at least one \
                         deadline (set `deadlines = [..]` or --deadline)"
                            .into(),
                    ));
                }
                Ok(Objective::DeadlineMiss {
                    deadlines: deadlines.to_vec(),
                })
            }
            "weighted-tardiness" | "tardiness" => {
                if deadlines.is_empty() {
                    return Err(Error::Config(
                        "objective weighted-tardiness needs at least \
                         one deadline (set `deadlines = [..]` or \
                         --deadline)"
                            .into(),
                    ));
                }
                Ok(Objective::WeightedTardiness {
                    deadlines: deadlines.to_vec(),
                })
            }
            other => Err(Error::Config(format!(
                "unknown objective {other:?}; expected weighted-sum | \
                 unweighted-sum | makespan | deadline-miss | \
                 weighted-tardiness"
            ))),
        }
    }

    /// The deadline applied to job `i` (`Tick::MAX` for objectives
    /// without deadlines).
    pub fn deadline(&self, i: usize) -> Tick {
        match self {
            Objective::DeadlineMiss { deadlines }
            | Objective::WeightedTardiness { deadlines }
                if !deadlines.is_empty() =>
            {
                deadlines[i % deadlines.len()]
            }
            _ => Tick::MAX,
        }
    }

    /// Fold one completed job into a running objective value.  The
    /// identity accumulator is `0` for every variant (sums add, makespan
    /// maxes).
    pub fn accumulate(
        &self,
        acc: u64,
        i: usize,
        job: &Job,
        end: Tick,
    ) -> u64 {
        let response = end - job.release;
        match self {
            Objective::WeightedSum => {
                acc + job.weight as u64 * response
            }
            Objective::UnweightedSum => acc + response,
            Objective::Makespan => acc.max(end),
            Objective::DeadlineMiss { .. } => {
                acc + u64::from(response > self.deadline(i))
            }
            Objective::WeightedTardiness { .. } => {
                acc + job.weight as u64
                    * response.saturating_sub(self.deadline(i))
            }
        }
    }

    /// Objective value of a finished schedule trace.
    pub fn evaluate(&self, jobs: &[Job], trace: &ScheduleTrace) -> u64 {
        trace.entries.iter().fold(0, |acc, e| {
            self.accumulate(acc, e.job, &jobs[e.job], e.end)
        })
    }

    /// Marginal cost of committing job `i` to finish at `end`, for myopic
    /// (online/greedy-style) solvers.  For `DeadlineMiss` a large miss
    /// penalty is tie-broken by the response time so the dispatcher still
    /// prefers faster machines among equal miss outcomes.
    pub fn marginal(&self, i: usize, job: &Job, end: Tick) -> u64 {
        let response = end - job.release;
        match self {
            Objective::WeightedSum => job.weight as u64 * response,
            Objective::UnweightedSum => response,
            Objective::Makespan => end,
            Objective::DeadlineMiss { .. } => {
                const MISS: u64 = 1 << 40;
                u64::from(response > self.deadline(i)) * MISS + response
            }
            Objective::WeightedTardiness { .. } => {
                // tardiness-dominant, response tie-break: among equally
                // (un)late placements the dispatcher still prefers the
                // faster machine
                job.weight as u64
                    * response.saturating_sub(self.deadline(i))
                    + response
            }
        }
    }

    /// Combine a (monotone) partial-schedule value with a suffix lower
    /// bound: additive objectives add, makespan maxes.
    pub fn combine(&self, partial: u64, suffix_bound: u64) -> u64 {
        match self {
            Objective::Makespan => partial.max(suffix_bound),
            _ => partial + suffix_bound,
        }
    }

    /// `bounds[k]` = lower bound on the objective contribution of jobs
    /// `k..`, each at its machine-minimal uncontended execution time —
    /// the eq.-6 bound generalized per objective.  The minimum ranges
    /// over the topology's concrete replicas (per-replica speed-scaled
    /// processing + per-replica link-scaled transmission): with unit
    /// factors it degenerates to the class-level bound, but a faster
    /// replica — or one on a faster link — can undercut every
    /// class-level time, so topology-independence would make the
    /// branch-and-bound pruning unsound.
    pub fn suffix_bounds(
        &self,
        jobs: &[Job],
        topo: &crate::topology::Topology,
    ) -> Vec<u64> {
        let machines = topo.machines();
        let mut bounds = vec![0u64; jobs.len() + 1];
        for k in (0..jobs.len()).rev() {
            let j = &jobs[k];
            let best = machines
                .iter()
                .map(|&m| {
                    topo.scaled_transmission(
                        j.transmission(m.class),
                        m,
                    ) + topo.scaled_processing(
                        j.processing(m.class),
                        m,
                    )
                })
                .min()
                .unwrap_or(0);
            let contrib = match self {
                Objective::WeightedSum => j.weight as u64 * best,
                Objective::UnweightedSum => best,
                Objective::Makespan => j.release + best,
                Objective::DeadlineMiss { .. } => {
                    u64::from(best > self.deadline(k))
                }
                // response >= best on every machine, so the clamped
                // lateness of `best` lower-bounds the real tardiness
                Objective::WeightedTardiness { .. } => {
                    j.weight as u64
                        * best.saturating_sub(self.deadline(k))
                }
            };
            bounds[k] = self.combine(contrib, bounds[k + 1]);
        }
        bounds
    }
}

impl std::fmt::Display for Objective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.key())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{paper_jobs, simulate, MachineRef, Topology};

    #[test]
    fn keys_cover_every_variant() {
        for key in Objective::KEYS {
            let obj = Objective::parse(key, &[30]).unwrap();
            assert_eq!(obj.key(), key);
        }
        assert_eq!(Objective::KEYS.len(), 5);
    }

    #[test]
    fn parse_roundtrips_keys() {
        for obj in [
            Objective::WeightedSum,
            Objective::UnweightedSum,
            Objective::Makespan,
            Objective::DeadlineMiss { deadlines: vec![30] },
            Objective::WeightedTardiness { deadlines: vec![30] },
        ] {
            let back = Objective::parse(obj.key(), &[30]).unwrap();
            assert_eq!(back, obj);
        }
        assert!(Objective::parse("banana", &[]).is_err());
        // deadline-carrying objectives without deadlines are rejected
        assert!(Objective::parse("deadline-miss", &[]).is_err());
        assert!(Objective::parse("weighted-tardiness", &[]).is_err());
        assert!(Objective::parse("tardiness", &[45]).is_ok());
    }

    #[test]
    fn evaluate_matches_schedule_sums() {
        let jobs = paper_jobs();
        let s = simulate(
            &jobs,
            &Topology::paper(),
            &vec![MachineRef::edge(0); jobs.len()],
        );
        assert_eq!(
            Objective::WeightedSum.evaluate(&jobs, &s.trace),
            s.weighted_sum
        );
        assert_eq!(
            Objective::UnweightedSum.evaluate(&jobs, &s.trace),
            s.unweighted_sum()
        );
        assert_eq!(
            Objective::Makespan.evaluate(&jobs, &s.trace),
            s.last_completion()
        );
    }

    #[test]
    fn deadline_miss_counts_and_broadcasts() {
        let jobs = paper_jobs();
        let s = simulate(
            &jobs,
            &Topology::paper(),
            &vec![MachineRef::DEVICE; jobs.len()],
        );
        // on the device every response equals proc_device (no queueing)
        let tight = Objective::DeadlineMiss { deadlines: vec![0] };
        assert_eq!(tight.evaluate(&jobs, &s.trace), jobs.len() as u64);
        let loose = Objective::DeadlineMiss { deadlines: vec![1000] };
        assert_eq!(loose.evaluate(&jobs, &s.trace), 0);
        // a single deadline broadcasts to every job index
        for i in 0..jobs.len() {
            assert_eq!(loose.deadline(i), 1000);
        }
    }

    #[test]
    fn suffix_bounds_dominated_by_real_schedules() {
        let jobs = paper_jobs();
        for topo in [
            Topology::paper(),
            // a fast replica shrinks the bound but must keep it sound
            Topology::heterogeneous(vec![1.0], vec![2.0, 0.5]).unwrap(),
            // ...and so does a fast (or Wi-Fi-slow) link
            Topology::with_links(1, 2, None, Some(vec![2.0, 0.5]))
                .unwrap(),
            Topology::with_factors(
                2,
                1,
                Some(vec![2.0, 1.0]),
                None,
                Some(vec![0.5, 2.0]),
                None,
            )
            .unwrap(),
        ] {
            for obj in [
                Objective::WeightedSum,
                Objective::UnweightedSum,
                Objective::Makespan,
                Objective::DeadlineMiss { deadlines: vec![10] },
                Objective::WeightedTardiness { deadlines: vec![10] },
            ] {
                let bounds = obj.suffix_bounds(&jobs, &topo);
                assert_eq!(bounds.len(), jobs.len() + 1);
                assert_eq!(bounds[jobs.len()], 0);
                // bounds[0] never exceeds any feasible schedule's value
                for m in topo.machines() {
                    let s = simulate(&jobs, &topo, &vec![m; jobs.len()]);
                    assert!(
                        bounds[0] <= obj.evaluate(&jobs, &s.trace),
                        "{obj}: bound {} beats schedule on {m}",
                        bounds[0]
                    );
                }
            }
        }
    }

    #[test]
    fn weighted_tardiness_semantics() {
        let jobs = paper_jobs();
        let s = simulate(
            &jobs,
            &Topology::paper(),
            &vec![MachineRef::DEVICE; jobs.len()],
        );
        // on the device every response equals proc_device (no queueing,
        // no transmission), so tardiness is directly checkable
        let tardy = Objective::WeightedTardiness { deadlines: vec![0] };
        let expected: u64 = jobs
            .iter()
            .map(|j| j.weight as u64 * j.proc_device)
            .sum();
        assert_eq!(tardy.evaluate(&jobs, &s.trace), expected);
        // a loose deadline zeroes the objective (nothing is late)
        let loose =
            Objective::WeightedTardiness { deadlines: vec![1000] };
        assert_eq!(loose.evaluate(&jobs, &s.trace), 0);
        // cycling: deadlines broadcast over job indices
        let cyc = Objective::WeightedTardiness {
            deadlines: vec![10, 20],
        };
        assert_eq!(cyc.deadline(0), 10);
        assert_eq!(cyc.deadline(3), 20);
        // marginal is tardiness-dominant with a response tie-break
        let j = &jobs[0];
        let d = Objective::WeightedTardiness { deadlines: vec![5] };
        let on_time = d.marginal(0, j, j.release + 5);
        let late = d.marginal(0, j, j.release + 6);
        assert_eq!(on_time, 5, "on-time marginal is the response alone");
        assert_eq!(late, j.weight as u64 + 6);
        assert!(late > on_time, "delaying never improves (monotone)");
    }

    #[test]
    fn suffix_bounds_unit_speeds_match_class_level() {
        // at unit factors the replica-aware bound degenerates to the
        // seed's class-level eq.-6 bound
        let jobs = paper_jobs();
        use crate::scheduler::MachineId;
        let class_best = |j: &crate::scheduler::Job| {
            MachineId::ALL
                .iter()
                .map(|&m| j.execution(m))
                .min()
                .unwrap()
        };
        let expected: u64 =
            jobs.iter().map(|j| j.weight as u64 * class_best(j)).sum();
        let bounds = Objective::WeightedSum
            .suffix_bounds(&jobs, &Topology::new(2, 3));
        assert_eq!(bounds[0], expected);
    }
}
