//! Poison-recovering wrappers over [`std::sync`] locking.
//!
//! A poisoned mutex means some thread panicked while holding the lock.
//! Every shared structure in this crate (interned label tables, the
//! timing wheel's slot map, shed gauges, the delay queue) stays
//! structurally valid across a panic — updates are single writes or
//! complete before any unwinding call — so the right recovery is to
//! take the guard and keep serving rather than cascade the panic
//! through every other worker via `.unwrap()`.  These helpers make
//! that policy explicit (and keep `bare-unwrap` findings out of the
//! lock paths).

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// Lock, recovering the guard if a previous holder panicked.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait`], recovering the guard on poison.
pub fn wait_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait_timeout`], recovering the guard on poison.
pub fn wait_timeout_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, dur)
        .unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn lock_unpoisoned_recovers_after_panic() {
        let m = Mutex::new(7u32);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison it");
        }));
        assert!(r.is_err());
        assert!(m.is_poisoned());
        assert_eq!(*lock_unpoisoned(&m), 7);
    }

    #[test]
    fn wait_timeout_unpoisoned_times_out() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let g = lock_unpoisoned(&m);
        let (_g, res) =
            wait_timeout_unpoisoned(&cv, g, Duration::from_millis(1));
        assert!(res.timed_out());
    }
}
