//! ASCII Gantt rendering of a schedule (Figures 7 and 8).

use crate::scheduler::Schedule;

/// Render a schedule as an ASCII Gantt chart: one row per job, `.` for
/// waiting-for-data, `-` for queued-at-machine, `#` for executing.  The
/// machine column names the concrete replica (`Edge:1`), so multi-replica
/// topologies read unambiguously; paper-topology labels are unchanged.
///
/// `width` caps the time axis (longer schedules are scaled down).
pub fn render_gantt(schedule: &Schedule, width: usize) -> String {
    let entries = schedule.trace.by_job();
    if entries.is_empty() {
        return String::from("(empty schedule)\n");
    }
    let horizon = schedule.last_completion().max(1);
    let scale = if horizon as usize <= width {
        1.0
    } else {
        width as f64 / horizon as f64
    };
    let to_col = |t: u64| -> usize { (t as f64 * scale).round() as usize };

    let mut out = String::new();
    out.push_str(&format!(
        "time 0..{horizon}  (whole response {}  last completion {})\n",
        schedule.trace.unweighted_sum(),
        schedule.last_completion()
    ));
    for e in &entries {
        let rel = to_col(e.release);
        let avail = to_col(e.available).max(rel);
        let start = to_col(e.start).max(avail);
        let end = to_col(e.end).max(start + 1);
        let mut line = String::new();
        line.push_str(&" ".repeat(rel));
        line.push_str(&".".repeat(avail - rel)); // transmitting
        line.push_str(&"-".repeat(start - avail)); // queued
        line.push_str(&"#".repeat(end - start)); // executing
        out.push_str(&format!(
            "J{:<3} {:<8} |{line}\n",
            e.job + 1,
            format!("{}", e.machine),
        ));
    }
    out
}

/// Per-replica utilization summary under the Gantt (the replica-scaling
/// narration for multi-edge runs).
pub fn render_replica_utilization(schedule: &Schedule) -> String {
    let mut out = String::new();
    for (m, u) in schedule.replica_utilization() {
        out.push_str(&format!("{:<8} {:>5.1}% busy\n", m.to_string(), u * 100.0));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Scenario;
    use crate::scheduler::{paper_jobs, Topology};

    #[test]
    fn renders_all_jobs() {
        let s = Scenario::paper().solve("tabu").unwrap();
        let g = render_gantt(&s, 100);
        for i in 1..=10 {
            assert!(g.contains(&format!("J{i}")), "missing J{i}\n{g}");
        }
        assert!(g.contains('#'));
    }

    #[test]
    fn empty_schedule() {
        let s = Scenario::builder()
            .jobs(Vec::new())
            .build()
            .unwrap()
            .solve("tabu")
            .unwrap();
        assert!(render_gantt(&s, 80).contains("empty"));
    }

    #[test]
    fn scales_long_horizons() {
        let s = Scenario::paper().solve("tabu").unwrap();
        let g = render_gantt(&s, 20);
        // no line should be drastically wider than the cap + labels
        for line in g.lines().skip(1) {
            assert!(line.len() < 60, "line too wide: {line}");
        }
    }

    #[test]
    fn replica_labels_appear_in_multi_edge_gantt() {
        // force jobs onto the second edge replica and check the row label
        let jobs = paper_jobs();
        let topo = Topology::new(1, 2);
        let assignment: Vec<_> = (0..jobs.len())
            .map(|i| crate::topology::MachineRef::edge(i % 2))
            .collect();
        let s = crate::scheduler::simulate(&jobs, &topo, &assignment);
        let g = render_gantt(&s, 100);
        assert!(g.contains("Edge:1"), "{g}");
        let util = render_replica_utilization(&s);
        assert!(util.contains("Edge:1"), "{util}");
        assert!(util.contains("Cloud"), "{util}");
    }
}
