//! Aligned monospace table rendering for CLI output.

/// A simple column-aligned text table.
#[derive(Debug, Default, Clone)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> Self {
        TextTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, title: impl Into<String>) -> Self {
        self.title = Some(title.into());
        self
    }

    /// Append a row (padded/truncated to the header width).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        let mut cells = cells;
        cells.resize(self.header.len(), String::new());
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Render with column alignment and a separator under the header.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{c:<width$}", width = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
                .trim_end()
                .to_string()
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(
            &widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  "),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment() {
        let mut t = TextTable::new(&["a", "bb"]);
        t.row(vec!["xxx".into(), "1".into()]);
        t.row(vec!["y".into(), "22".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines[0], "a    bb");
        assert_eq!(lines[1], "---  --");
        assert_eq!(lines[2], "xxx  1");
        assert_eq!(lines[3], "y    22");
    }

    #[test]
    fn short_rows_padded() {
        let mut t = TextTable::new(&["a", "b", "c"]);
        t.row(vec!["1".into()]);
        assert!(t.render().contains('1'));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn title_rendered() {
        let t = TextTable::new(&["x"]).with_title("Table V");
        assert!(t.render().starts_with("Table V\n"));
    }
}
