//! Report rendering: aligned text tables, ASCII Gantt charts (Figures 7–8),
//! and CSV emitters for figure series.

mod gantt;
mod table;

pub use gantt::{render_gantt, render_replica_utilization};
pub use table::TextTable;

use std::fmt::Write as _;

/// Render rows of `(series, x, y)` as a CSV string (figure data series).
pub fn csv_series(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{}", header.join(","));
    for row in rows {
        let _ = writeln!(out, "{}", row.join(","));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_shape() {
        let csv = csv_series(
            &["wl", "layer", "ms"],
            &[vec!["WL1-1".into(), "edge".into(), "12".into()]],
        );
        let mut lines = csv.lines();
        assert_eq!(lines.next().unwrap(), "wl,layer,ms");
        assert_eq!(lines.next().unwrap(), "WL1-1,edge,12");
        assert!(lines.next().is_none());
    }
}
