//! PJRT inference runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`)
//! and executes them on the request path.  Python is never involved here —
//! the artifacts were lowered once by `make artifacts`.
//!
//! Interchange is HLO *text* (see python/compile/aot.py for why), parsed by
//! `HloModuleProto::from_text_file`, compiled by the PJRT CPU client, and
//! cached per (application, batch) variant.

mod manifest;

pub use manifest::{Manifest, ManifestEntry};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::workload::Application;
use crate::{Error, Result};

/// The result of one batched inference call.
#[derive(Debug, Clone)]
pub struct InferenceOutput {
    /// Sigmoid probabilities, row-major `(batch, output_dim)`.
    pub probs: Vec<f32>,
    pub batch: usize,
    pub output_dim: usize,
    /// Pure execute time (excludes any emulation padding).
    pub elapsed: Duration,
}

impl InferenceOutput {
    /// Probabilities of one batch row.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.probs[i * self.output_dim..(i + 1) * self.output_dim]
    }
}

/// Loads, compiles, caches and executes the model variants.
///
/// Thread-safe: executables compile lazily under a mutex and execution
/// itself is internally synchronized by PJRT.
pub struct InferenceRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    /// Lazily compiled executables per (app, batch).
    cache: Mutex<HashMap<(Application, usize), std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl std::fmt::Debug for InferenceRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("InferenceRuntime")
            .field("dir", &self.dir)
            .field("variants", &self.manifest.entries.len())
            .finish()
    }
}

impl InferenceRuntime {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(InferenceRuntime {
            client,
            manifest,
            dir,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Batch sizes available for an application, ascending.
    pub fn batch_sizes(&self, app: Application) -> Vec<usize> {
        self.manifest.batch_sizes(app)
    }

    /// Smallest compiled batch size that fits `n` rows (or the largest
    /// available if `n` exceeds them all — caller splits).
    pub fn pick_batch(&self, app: Application, n: usize) -> Result<usize> {
        let sizes = self.batch_sizes(app);
        sizes
            .iter()
            .copied()
            .find(|&b| b >= n)
            .or_else(|| sizes.last().copied())
            .ok_or_else(|| Error::MissingVariant { app: app.key().into(), batch: n })
    }

    /// Eagerly compile every variant (used at server startup so the first
    /// request doesn't pay compile time).
    pub fn warmup(&self) -> Result<()> {
        for e in &self.manifest.entries {
            let app: Application = e.app.parse()?;
            self.executable(app, e.batch)?;
        }
        Ok(())
    }

    /// Get (compiling if needed) the executable for a variant.
    fn executable(
        &self,
        app: Application,
        batch: usize,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) =
            crate::sync::lock_unpoisoned(&self.cache).get(&(app, batch))
        {
            return Ok(exe.clone());
        }
        // compile outside the lock would risk duplicate work but never
        // deadlock; we keep it simple and compile under the lock since
        // startup warms everything anyway.
        let mut cache = crate::sync::lock_unpoisoned(&self.cache);
        if let Some(exe) = cache.get(&(app, batch)) {
            return Ok(exe.clone());
        }
        let entry = self.manifest.entry(app, batch).ok_or_else(|| {
            Error::MissingVariant { app: app.key().into(), batch }
        })?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        cache.insert((app, batch), exe.clone());
        Ok(exe)
    }

    /// Execute one batched inference.
    ///
    /// `input` must hold exactly `batch × seq_len × input_dim` f32 values,
    /// time-major per row (the layout [`crate::data::EpisodeGenerator`]
    /// produces).  Short batches must be padded by the caller (the
    /// coordinator's batcher does this).
    pub fn infer(
        &self,
        app: Application,
        batch: usize,
        input: &[f32],
    ) -> Result<InferenceOutput> {
        let expected = batch * app.seq_len() * app.input_dim();
        if input.len() != expected {
            return Err(Error::ShapeMismatch { expected, got: input.len() });
        }
        let exe = self.executable(app, batch)?;
        let start = Instant::now();
        let literal = xla::Literal::vec1(input).reshape(&[
            batch as i64,
            app.seq_len() as i64,
            app.input_dim() as i64,
        ])?;
        let result = exe.execute::<xla::Literal>(&[literal])?[0][0]
            .to_literal_sync()?;
        // AOT lowers with return_tuple=True → 1-tuple
        let out = result.to_tuple1()?;
        let probs = out.to_vec::<f32>()?;
        let elapsed = start.elapsed();
        let output_dim = app.output_dim();
        if probs.len() != batch * output_dim {
            return Err(Error::ShapeMismatch {
                expected: batch * output_dim,
                got: probs.len(),
            });
        }
        Ok(InferenceOutput { probs, batch, output_dim, elapsed })
    }

    /// Run `rows` (possibly exceeding the largest compiled batch) by
    /// splitting into compiled-size chunks with zero-padding on the tail.
    pub fn infer_rows(
        &self,
        app: Application,
        rows: usize,
        input: &[f32],
    ) -> Result<InferenceOutput> {
        let row_len = app.seq_len() * app.input_dim();
        if input.len() != rows * row_len {
            return Err(Error::ShapeMismatch {
                expected: rows * row_len,
                got: input.len(),
            });
        }
        let mut probs = Vec::with_capacity(rows * app.output_dim());
        let mut elapsed = Duration::ZERO;
        let mut done = 0usize;
        while done < rows {
            let n = (rows - done).min(*self.batch_sizes(app).last().unwrap_or(&1));
            let b = self.pick_batch(app, n)?;
            let mut chunk = vec![0.0f32; b * row_len];
            chunk[..n * row_len]
                .copy_from_slice(&input[done * row_len..(done + n) * row_len]);
            let out = self.infer(app, b, &chunk)?;
            probs.extend_from_slice(&out.probs[..n * app.output_dim()]);
            elapsed += out.elapsed;
            done += n;
        }
        Ok(InferenceOutput {
            probs,
            batch: rows,
            output_dim: app.output_dim(),
            elapsed,
        })
    }
}
