//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the rust runtime.

use std::path::Path;

use crate::serialize::{json, Value};
use crate::workload::Application;
use crate::{Error, Result};

/// One compiled model variant.
#[derive(Debug, Clone, PartialEq)]
pub struct ManifestEntry {
    pub app: String,
    pub title: String,
    pub batch: usize,
    pub seq_len: usize,
    pub input_dim: usize,
    pub output_dim: usize,
    pub hidden: usize,
    pub param_count: u64,
    pub priority: u32,
    pub file: String,
    pub sha256_16: String,
}

/// The artifact manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub version: u32,
    pub seed: u64,
    pub dtype: String,
    pub entries: Vec<ManifestEntry>,
}

impl ManifestEntry {
    /// Parse one entry object.
    fn from_value(v: &Value) -> Result<Self> {
        let s = |k: &str| -> Result<String> {
            v.req(k)?
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| Error::Json(format!("{k} must be a string")))
        };
        let n = |k: &str| -> Result<u64> {
            v.req(k)?
                .as_u64()
                .ok_or_else(|| Error::Json(format!("{k} must be an integer")))
        };
        Ok(ManifestEntry {
            app: s("app")?,
            title: s("title")?,
            batch: n("batch")? as usize,
            seq_len: n("seq_len")? as usize,
            input_dim: n("input_dim")? as usize,
            output_dim: n("output_dim")? as usize,
            hidden: n("hidden")? as usize,
            param_count: n("param_count")?,
            priority: n("priority")? as u32,
            file: s("file")?,
            sha256_16: s("sha256_16")?,
        })
    }
}

impl Manifest {
    /// Load and validate from `manifest.json`.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(path.display().to_string(), e))?;
        let m = Self::from_json(&text)?;
        m.validate()?;
        Ok(m)
    }

    /// Parse from JSON text (the document python/compile/aot.py writes).
    pub fn from_json(text: &str) -> Result<Self> {
        let v = json::parse(text)?;
        let entries = v
            .req("entries")?
            .as_array()
            .ok_or_else(|| Error::Json("entries must be an array".into()))?
            .iter()
            .map(ManifestEntry::from_value)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            version: v.req("version")?.as_u64().unwrap_or(0) as u32,
            seed: v.req("seed")?.as_u64().unwrap_or(0),
            dtype: v
                .req("dtype")?
                .as_str()
                .ok_or_else(|| Error::Json("dtype must be a string".into()))?
                .to_string(),
            entries,
        })
    }

    /// Consistency checks against the compiled-in application catalog.
    pub fn validate(&self) -> Result<()> {
        if self.entries.is_empty() {
            return Err(Error::Artifact("manifest has no entries".into()));
        }
        for e in &self.entries {
            let app: Application = e.app.parse().map_err(|_| {
                Error::Artifact(format!("unknown app {:?} in manifest", e.app))
            })?;
            if e.input_dim != app.input_dim()
                || e.output_dim != app.output_dim()
                || e.seq_len != app.seq_len()
            {
                return Err(Error::Artifact(format!(
                    "manifest entry {}/b{} shape mismatch vs catalog",
                    e.app, e.batch
                )));
            }
            if e.param_count != app.paper_flops() {
                return Err(Error::Artifact(format!(
                    "manifest entry {} param_count {} != paper {}",
                    e.app,
                    e.param_count,
                    app.paper_flops()
                )));
            }
            if e.batch == 0 {
                return Err(Error::Artifact("batch 0 variant".into()));
            }
        }
        Ok(())
    }

    /// The entry for a variant, if present.
    pub fn entry(&self, app: Application, batch: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .find(|e| e.app == app.key() && e.batch == batch)
    }

    /// Compiled batch sizes for an app, ascending.
    pub fn batch_sizes(&self, app: Application) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .iter()
            .filter(|e| e.app == app.key())
            .map(|e| e.batch)
            .collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(app: &str, batch: usize) -> ManifestEntry {
        let a: Application = app.parse().unwrap();
        ManifestEntry {
            app: app.into(),
            title: a.title().into(),
            batch,
            seq_len: a.seq_len(),
            input_dim: a.input_dim(),
            output_dim: a.output_dim(),
            hidden: a.hidden(),
            param_count: a.paper_flops(),
            priority: a.priority(),
            file: format!("{app}_b{batch}.hlo.txt"),
            sha256_16: "0".repeat(16),
        }
    }

    fn manifest() -> Manifest {
        Manifest {
            version: 1,
            seed: 0,
            dtype: "f32".into(),
            entries: vec![
                entry("breath", 1),
                entry("breath", 8),
                entry("mortality", 1),
            ],
        }
    }

    #[test]
    fn valid_manifest_passes() {
        manifest().validate().unwrap();
    }

    #[test]
    fn batch_sizes_sorted() {
        assert_eq!(manifest().batch_sizes(Application::Breath), vec![1, 8]);
        assert!(manifest().batch_sizes(Application::Phenotype).is_empty());
    }

    #[test]
    fn entry_lookup() {
        let m = manifest();
        assert!(m.entry(Application::Breath, 8).is_some());
        assert!(m.entry(Application::Breath, 32).is_none());
    }

    #[test]
    fn wrong_shape_rejected() {
        let mut m = manifest();
        m.entries[0].input_dim = 99;
        assert!(m.validate().is_err());
    }

    #[test]
    fn wrong_param_count_rejected() {
        let mut m = manifest();
        m.entries[0].param_count = 1;
        assert!(m.validate().is_err());
    }

    #[test]
    fn unknown_app_rejected() {
        let mut m = manifest();
        m.entries[0].app = "ecg".into();
        assert!(m.validate().is_err());
    }

    #[test]
    fn empty_rejected() {
        let mut m = manifest();
        m.entries.clear();
        assert!(m.validate().is_err());
    }
}
