//! Integration: config loading from disk + serialization substrate
//! round-trips under randomized documents.

use edgeward::config::Config;
use edgeward::data::Rng;
use edgeward::serialize::{json, toml, Value};

#[test]
fn load_config_from_file() {
    let dir = std::env::temp_dir().join(format!(
        "edgeward-test-{}-{}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.toml");
    std::fs::write(
        &path,
        "seed = 77\n\n[serve]\npatients = 2\n\n[scheduler]\nmax_iters = 10\n",
    )
    .unwrap();
    let cfg = Config::load(&path).unwrap();
    assert_eq!(cfg.seed, 77);
    assert_eq!(cfg.serve.patients, 2);
    assert_eq!(cfg.scheduler.max_iters, 10);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn missing_config_file_errors_with_path() {
    let err = Config::load("/nonexistent/edgeward.toml").unwrap_err();
    assert!(err.to_string().contains("edgeward.toml"), "{err}");
}

/// Random JSON documents round-trip parse(to_string(v)) == v.
#[test]
fn prop_json_roundtrip_random_documents() {
    fn random_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.bernoulli(0.5)),
            2 => {
                // integers round-trip exactly; keep magnitude < 2^53
                Value::Number((rng.below(1 << 50) as i64
                    - (1i64 << 49)) as f64)
            }
            3 => {
                let len = rng.below(12) as usize;
                let s: String = (0..len)
                    .map(|_| {
                        // mix of ascii, escapes, and multibyte
                        match rng.below(6) {
                            0 => '"',
                            1 => '\\',
                            2 => '\n',
                            3 => 'é',
                            4 => '😀',
                            _ => (b'a' + rng.below(26) as u8) as char,
                        }
                    })
                    .collect();
                Value::String(s)
            }
            4 => {
                let n = rng.below(4) as usize;
                Value::Array(
                    (0..n).map(|_| random_value(rng, depth - 1)).collect(),
                )
            }
            _ => {
                let n = rng.below(4) as usize;
                Value::Object(
                    (0..n)
                        .map(|i| {
                            (
                                format!("k{i}"),
                                random_value(rng, depth - 1),
                            )
                        })
                        .collect(),
                )
            }
        }
    }

    for seed in 0..300 {
        let mut rng = Rng::new(seed);
        let v = random_value(&mut rng, 3);
        let text = v.to_string();
        let back = json::parse(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, v, "seed {seed}: {text}");
        // pretty printing parses to the same value
        let back2 = json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(back2, v, "seed {seed} (pretty)");
    }
}

/// The default config's TOML emission parses back identically, and random
/// scalar mutations keep the document parseable.
#[test]
fn prop_toml_emit_parse_stability() {
    let cfg = Config::default();
    let text = cfg.to_toml();
    for seed in 0..50 {
        let mut rng = Rng::new(seed);
        // mutate one numeric literal in the text
        let mut mutated = String::new();
        let mut replaced = false;
        for line in text.lines() {
            if !replaced
                && rng.bernoulli(0.2)
                && line.contains('=')
                && !line.contains('"')
                && !line.contains('[')
            {
                let (k, _) = line.split_once('=').unwrap();
                mutated.push_str(&format!("{k}= {}\n", rng.below(1000)));
                replaced = true;
            } else {
                mutated.push_str(line);
                mutated.push('\n');
            }
        }
        // must still parse as TOML (config validation may reject values,
        // but the *parser* must not crash or mis-parse)
        toml::parse(&mutated)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}\n{mutated}"));
    }
}

#[test]
fn manifest_json_parses_via_substrate() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let text = std::fs::read_to_string("artifacts/manifest.json").unwrap();
    let m = edgeward::runtime::Manifest::from_json(&text).unwrap();
    m.validate().unwrap();
    assert_eq!(m.entries.len() % 3, 0, "three apps × batch variants");
}
