//! Registry-wide golden tests: every registered solver runs on
//! [`Scenario::paper`] and the Table VII fixed-layer rows reproduce the
//! paper's published numbers bit-for-bit (416/100, 291, 366/94), plus
//! end-to-end coverage of the `Scenario` front door (TOML specs,
//! objective threading, seeded reproducibility).

use edgeward::scenario::{
    solver, solver_names, Arrival, Objective, Scenario, SOLVERS,
};
use edgeward::scheduler::{paper_jobs, Schedule, Topology};

/// C1/C4 sanity on any finished schedule.
fn check_schedule(s: &Schedule, jobs: usize, ctx: &str) {
    assert_eq!(s.assignment.len(), jobs, "{ctx}: coverage");
    assert_eq!(s.trace.entries.len(), jobs, "{ctx}: trace");
    for e in &s.trace.entries {
        assert!(s.topology.contains(e.machine), "{ctx}: replica range");
        assert!(e.start >= e.available, "{ctx}: starts before data");
    }
}

#[test]
fn every_registered_solver_handles_the_paper_scenario() {
    let paper = Scenario::paper();
    for spec in SOLVERS {
        let s = paper
            .solve(spec.name)
            .unwrap_or_else(|e| panic!("{} failed: {e}", spec.name));
        check_schedule(&s, paper.jobs.len(), spec.name);
        // the objective value reported through the scenario equals the
        // schedule's own eq.-5 sum under the default objective
        assert_eq!(paper.evaluate(&s), s.weighted_sum, "{}", spec.name);
    }
}

#[test]
fn golden_table_vii_rows_bit_for_bit() {
    let paper = Scenario::paper();
    // the paper's Table VII fixed-layer rows (cloud/edge label swap
    // documented in DESIGN.md §5)
    let cloud = paper.solve("all-cloud").unwrap();
    assert_eq!(cloud.unweighted_sum(), 416);
    assert_eq!(cloud.last_completion(), 100);
    let edge = paper.solve("all-edge").unwrap();
    assert_eq!(edge.unweighted_sum(), 291);
    let device = paper.solve("all-device").unwrap();
    assert_eq!(device.unweighted_sum(), 366);
    assert_eq!(device.last_completion(), 94);
    // ours beats every baseline on both published columns
    let ours = paper.solve("tabu").unwrap();
    for name in ["per-job-optimal", "all-cloud", "all-edge", "all-device"]
    {
        let base = paper.solve(name).unwrap();
        assert!(
            ours.unweighted_sum() <= base.unweighted_sum(),
            "tabu lost to {name}"
        );
    }
    // and the optimum bounds ours
    let exact = paper.solve("exact").unwrap();
    assert!(exact.weighted_sum <= ours.weighted_sum);
    let online = paper.solve("online").unwrap();
    assert!(online.weighted_sum >= exact.weighted_sum);
    let greedy = paper.solve("greedy").unwrap();
    assert!(ours.weighted_sum <= greedy.weighted_sum);
}

#[test]
fn registry_is_complete_and_aliased() {
    let names = solver_names();
    for expected in [
        "tabu",
        "greedy",
        "exact",
        "online",
        "per-job-optimal",
        "all-cloud",
        "all-edge",
        "all-device",
        "lns",
        "per-job-optimal-scaled",
    ] {
        assert!(names.contains(&expected), "missing {expected}");
    }
    // the paper's name for Algorithm 2 resolves
    assert_eq!(solver("ours").unwrap().name(), "tabu");
    assert_eq!(solver("large-neighborhood").unwrap().name(), "lns");
    assert_eq!(
        solver("per-job-scaled").unwrap().name(),
        "per-job-optimal-scaled"
    );
    assert!(solver("no-such-solver").is_err());
}

#[test]
fn objective_threading_reaches_every_solver() {
    // under Makespan, the exact solver's makespan bounds everyone else's
    let mk = |objective: Objective| {
        Scenario::builder()
            .jobs(paper_jobs().into_iter().take(7).collect())
            .objective(objective.clone())
            .build()
            .unwrap_or_else(|e| {
                panic!("building the 7-job {objective} scenario: {e}")
            })
    };
    let scenario = mk(Objective::Makespan);
    let optimum = scenario.evaluate(&scenario.solve("exact").unwrap());
    for name in solver_names() {
        let s = scenario.solve(name).unwrap();
        assert!(
            scenario.evaluate(&s) >= optimum,
            "{name} beat the exact makespan optimum?!"
        );
    }
    // under DeadlineMiss the tabu solver never misses more than the
    // greedy seed it starts from
    let scenario = mk(Objective::DeadlineMiss { deadlines: vec![20] });
    let tabu = scenario.evaluate(&scenario.solve("tabu").unwrap());
    let greedy = scenario.evaluate(&scenario.solve("greedy").unwrap());
    assert!(tabu <= greedy);
}

#[test]
fn generated_scenarios_run_end_to_end_and_reproduce() {
    for arrival in [
        Arrival::PoissonWard { jobs: 9, rate: 0.3 },
        Arrival::CodeBlueSurge {
            baseline: 6,
            rate: 0.2,
            surge: 3,
            surge_at: 25,
        },
        Arrival::DiurnalWard {
            jobs: 9,
            rate: 0.3,
            amplitude: 0.7,
            period: 40,
        },
    ] {
        let build = |seed: u64| {
            Scenario::builder()
                .arrival(arrival.clone())
                .seed(seed)
                .topology(
                    Topology::try_new(1, 2)
                        .expect("1c+2e is a valid topology"),
                )
                .objective(Objective::Makespan)
                .build()
                .unwrap_or_else(|e| {
                    panic!("building {arrival} seed {seed}: {e}")
                })
        };
        let solve = |s: &Scenario, name: &str| {
            s.solve(name).unwrap_or_else(|e| {
                panic!("{name} on {}: {e}", s.label())
            })
        };
        let a = build(11);
        let b = build(11);
        assert_eq!(a.jobs, b.jobs, "same seed, same scenario");
        let sa = solve(&a, "tabu");
        let sb = solve(&b, "tabu");
        assert_eq!(sa.assignment, sb.assignment, "deterministic solve");
        check_schedule(&sa, a.jobs.len(), "generated");
        // the tabu plan is never worse than greedy under the objective
        assert!(a.evaluate(&sa) <= a.evaluate(&solve(&a, "greedy")));
    }
}

#[test]
fn every_registered_solver_handles_a_heterogeneous_scenario() {
    // a big.LITTLE edge room through the whole registry: every solver
    // produces a valid schedule and none beats the exact optimum
    let scenario = Scenario::builder()
        .jobs(paper_jobs().into_iter().take(7).collect())
        .topology(
            Topology::heterogeneous(vec![1.0], vec![2.0, 0.5])
                .expect("valid speeds"),
        )
        .build()
        .unwrap();
    let optimum = scenario.evaluate(&scenario.solve("exact").unwrap());
    for spec in SOLVERS {
        let s = scenario
            .solve(spec.name)
            .unwrap_or_else(|e| panic!("{} failed: {e}", spec.name));
        check_schedule(&s, scenario.jobs.len(), spec.name);
        assert!(
            scenario.evaluate(&s) >= optimum,
            "{} beat the heterogeneous optimum?!",
            spec.name
        );
    }
}

#[test]
fn toml_scenario_end_to_end() {
    // the acceptance-criteria flow: a Poisson-ward TOML spec solved
    // under makespan by the tabu solver
    let text = "\
[scenario]
arrival = \"poisson-ward\"
jobs = 10
rate = 0.4
seed = 99
objective = \"makespan\"

[scenario.topology]
clouds = 1
edges = 2
";
    let scenario = Scenario::from_toml(text)
        .unwrap_or_else(|e| panic!("parsing the ward spec: {e}\n{text}"));
    assert_eq!(scenario.jobs.len(), 10);
    let s = scenario
        .solve("tabu")
        .unwrap_or_else(|e| panic!("tabu on the toml ward: {e}"));
    check_schedule(&s, 10, "toml ward");
    assert_eq!(scenario.evaluate(&s), s.last_completion());
}

#[test]
fn invalid_topologies_are_typed_errors_not_panics() {
    // the satellite fix: a 0-replica topology surfaces as
    // Error::InvalidTopology from the front door, not a panic inside
    // simulate
    let err = Scenario::builder()
        .topology(Topology::new(1, 0))
        .build()
        .unwrap_err();
    assert!(
        matches!(err, edgeward::Error::InvalidTopology { .. }),
        "{err:?}"
    );
    // even a hand-mutated scenario fails loudly in every solver
    let mut scenario = Scenario::paper();
    scenario.topology = Topology::new(1, 0);
    for spec in SOLVERS {
        match scenario.solve(spec.name) {
            Err(edgeward::Error::InvalidTopology { .. }) => {}
            other => panic!("{}: expected typed error, got {other:?}", spec.name),
        }
    }
}
