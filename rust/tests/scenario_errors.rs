//! Error-path coverage for the `Scenario` front door: every malformed
//! spec surfaces as the right *typed* [`edgeward::Error`] variant, never
//! a panic and never a stringly-typed catch-all where a structured
//! variant exists.

use edgeward::scenario::{solver, Arrival, Objective, Scenario};
use edgeward::Error;

#[test]
fn load_missing_file_is_a_typed_io_error_naming_the_path() {
    let err = Scenario::load("/nonexistent/ward.toml").unwrap_err();
    match &err {
        Error::Io { path, .. } => {
            assert!(path.contains("ward.toml"), "{path}")
        }
        other => panic!("expected Error::Io, got {other:?}"),
    }
    assert!(err.to_string().contains("ward.toml"), "{err}");
}

#[test]
fn toml_syntax_errors_are_toml_variants() {
    for bad in ["[scenario", "arrival = ", "= 3"] {
        match Scenario::from_toml(bad).unwrap_err() {
            Error::Toml(_) => {}
            other => panic!("{bad:?}: expected Error::Toml, got {other:?}"),
        }
    }
}

#[test]
fn scenario_section_must_be_a_table() {
    match Scenario::from_toml("scenario = 1\n").unwrap_err() {
        Error::Config(msg) => assert!(msg.contains("table"), "{msg}"),
        other => panic!("expected Error::Config, got {other:?}"),
    }
}

#[test]
fn empty_spec_falls_back_to_the_paper_scenario() {
    // no [scenario] section at all is not an error: the spec defaults to
    // the paper experiment (fields may also sit at top level)
    let s = Scenario::from_toml("").unwrap();
    assert_eq!(s.jobs, edgeward::scheduler::paper_jobs());
    // but an unknown *section* is rejected loudly
    match Scenario::from_toml("[banana]\nx = 1\n").unwrap_err() {
        Error::Config(msg) => assert!(msg.contains("banana"), "{msg}"),
        other => panic!("{other:?}"),
    }
}

#[test]
fn unknown_arrival_key_is_a_config_error_listing_the_choices() {
    let err =
        Scenario::from_toml("[scenario]\narrival = \"meteor\"\n")
            .unwrap_err();
    match &err {
        Error::Config(msg) => {
            assert!(msg.contains("meteor"), "{msg}");
            assert!(msg.contains("diurnal-ward"), "{msg}");
        }
        other => panic!("{other:?}"),
    }
    assert!(matches!(Arrival::parse("meteor"), Err(Error::Config(_))));
}

#[test]
fn unknown_objective_key_is_a_config_error() {
    let err =
        Scenario::from_toml("[scenario]\nobjective = \"profit\"\n")
            .unwrap_err();
    match &err {
        Error::Config(msg) => assert!(msg.contains("profit"), "{msg}"),
        other => panic!("{other:?}"),
    }
    assert!(matches!(
        Objective::parse("profit", &[]),
        Err(Error::Config(_))
    ));
    // deadline-miss without deadlines is rejected up front
    assert!(matches!(
        Scenario::from_toml(
            "[scenario]\nobjective = \"deadline-miss\"\n"
        ),
        Err(Error::Config(_))
    ));
}

#[test]
fn unknown_solver_key_is_a_config_error_listing_the_registry() {
    let err = Scenario::paper().solve("annealing").unwrap_err();
    match &err {
        Error::Config(msg) => {
            assert!(msg.contains("annealing"), "{msg}");
            assert!(msg.contains("tabu"), "{msg}");
        }
        other => panic!("{other:?}"),
    }
    assert!(matches!(solver("annealing"), Err(Error::Config(_))));
}

#[test]
fn invalid_topology_is_the_invalid_topology_variant() {
    let err = Scenario::from_toml(
        "[scenario]\n\n[scenario.topology]\nclouds = 0\nedges = 3\n",
    )
    .unwrap_err();
    match err {
        Error::InvalidTopology { clouds, edges, .. } => {
            assert_eq!((clouds, edges), (0, 3));
        }
        other => panic!("expected InvalidTopology, got {other:?}"),
    }
}

#[test]
fn invalid_speed_factors_are_invalid_topology_variants() {
    for (bad, why) in [
        (
            "[scenario]\n\n[scenario.topology]\nedges = 2\n\
             edge_speeds = [1.5]\n",
            "length mismatch",
        ),
        (
            "[scenario]\n\n[scenario.topology]\nedge_speeds = [0.0]\n",
            "zero factor",
        ),
        (
            "[scenario]\n\n[scenario.topology]\n\
             cloud_speeds = [-2.0]\n",
            "negative factor",
        ),
        (
            "[scenario]\n\n[scenario.topology]\n\
             cloud_speeds = [1000.0]\n",
            "absurd factor",
        ),
    ] {
        match Scenario::from_toml(bad).unwrap_err() {
            Error::InvalidTopology { reason, .. } => {
                assert!(!reason.is_empty(), "{why}")
            }
            other => {
                panic!("{why}: expected InvalidTopology, got {other:?}")
            }
        }
    }
    // a non-numeric entry is a config (type) error from the reader
    assert!(matches!(
        Scenario::from_toml(
            "[scenario]\n\n[scenario.topology]\n\
             edge_speeds = [\"fast\"]\n"
        ),
        Err(Error::Config(_))
    ));
}

#[test]
fn invalid_link_factors_are_invalid_topology_variants() {
    for (bad, why) in [
        (
            "[scenario]\n\n[scenario.topology]\nedges = 2\n\
             edge_links = [1.5]\n",
            "length mismatch",
        ),
        (
            "[scenario]\n\n[scenario.topology]\nedge_links = [0.0]\n",
            "zero factor",
        ),
        (
            "[scenario]\n\n[scenario.topology]\n\
             cloud_links = [-2.0]\n",
            "negative factor",
        ),
        (
            "[scenario]\n\n[scenario.topology]\n\
             cloud_links = [1000.0]\n",
            "absurd factor",
        ),
        (
            // speeds and links must agree on the replica count
            "[scenario]\n\n[scenario.topology]\n\
             edge_speeds = [1.5, 0.75]\nedge_links = [0.5]\n",
            "speed/link length disagreement",
        ),
    ] {
        match Scenario::from_toml(bad).unwrap_err() {
            Error::InvalidTopology { reason, .. } => {
                assert!(!reason.is_empty(), "{why}")
            }
            other => {
                panic!("{why}: expected InvalidTopology, got {other:?}")
            }
        }
    }
    // a non-numeric entry is a config (type) error from the reader
    assert!(matches!(
        Scenario::from_toml(
            "[scenario]\n\n[scenario.topology]\n\
             edge_links = [\"wifi\"]\n"
        ),
        Err(Error::Config(_))
    ));
}

#[test]
fn degenerate_arrival_parameters_are_config_errors() {
    for bad in [
        // zero rate
        "[scenario]\narrival = \"poisson-ward\"\nrate = 0.0\n",
        // diurnal amplitude out of range
        "[scenario]\narrival = \"diurnal-ward\"\namplitude = 2.0\n",
        // diurnal period of zero ticks
        "[scenario]\narrival = \"diurnal-ward\"\nperiod = 0\n",
    ] {
        match Scenario::from_toml(bad).unwrap_err() {
            Error::Config(_) => {}
            other => panic!("{bad:?}: expected Config, got {other:?}"),
        }
    }
}

#[test]
fn unknown_and_misplaced_fields_are_named_in_the_error() {
    // a typo'd field
    match Scenario::from_toml("[scenario]\nseeed = 7\n").unwrap_err() {
        Error::Config(msg) => assert!(msg.contains("seeed"), "{msg}"),
        other => panic!("{other:?}"),
    }
    // a sizing field belonging to a different arrival process
    assert!(matches!(
        Scenario::from_toml(
            "[scenario]\narrival = \"paper-trace\"\nperiod = 48\n"
        ),
        Err(Error::Config(_))
    ));
}
