//! Property-based tests on scheduler invariants.
//!
//! The offline build has no proptest crate, so random-input property
//! testing is driven by the in-tree deterministic RNG: 200 random job sets
//! per property, with the failing seed printed for reproduction
//! (substitution ledger, DESIGN.md §3).

use edgeward::data::Rng;
use edgeward::scheduler::{
    evaluate_strategy, greedy_assignment, lower_bound, paper_jobs,
    schedule_jobs, simulate, Job, MachineId, Schedule, SchedulerParams,
    Strategy,
};

const CASES: u64 = 200;

/// Random job set in the paper's regime.
fn random_jobs(rng: &mut Rng) -> Vec<Job> {
    let n = 1 + rng.below(15) as usize;
    let mut release = 0;
    (0..n)
        .map(|_| {
            release += rng.below(6);
            Job {
                release,
                weight: 1 + rng.below(3) as u32,
                proc_cloud: 1 + rng.below(10),
                trans_cloud: 1 + rng.below(70),
                proc_edge: 1 + rng.below(15),
                trans_edge: 1 + rng.below(15),
                proc_device: 1 + rng.below(80),
            }
        })
        .collect()
}

fn check_schedule_invariants(jobs: &[Job], s: &Schedule, ctx: &str) {
    assert_eq!(s.assignment.len(), jobs.len(), "{ctx}: coverage");
    assert_eq!(s.trace.entries.len(), jobs.len(), "{ctx}: trace");

    // per-job invariants
    for e in &s.trace.entries {
        let j = &jobs[e.job];
        let m = s.assignment[e.job];
        assert_eq!(e.machine, m, "{ctx}: machine mismatch");
        assert_eq!(e.release, j.release, "{ctx}");
        assert_eq!(e.available, j.release + j.transmission(m), "{ctx}");
        assert!(e.start >= e.available, "{ctx}: start before data arrives");
        assert_eq!(e.end, e.start + j.processing(m), "{ctx}: duration");
        if m == MachineId::Device {
            assert_eq!(e.start, e.available, "{ctx}: device queued");
        }
    }

    // exclusive machines never overlap (C1)
    for m in [MachineId::Cloud, MachineId::Edge] {
        let mut slots: Vec<(u64, u64)> = s
            .trace
            .entries
            .iter()
            .filter(|e| e.machine == m)
            .map(|e| (e.start, e.end))
            .collect();
        slots.sort_unstable();
        for w in slots.windows(2) {
            assert!(w[0].1 <= w[1].0, "{ctx}: overlap on {m:?}: {w:?}");
        }
    }

    // objective consistency
    let weights: Vec<u32> = jobs.iter().map(|j| j.weight).collect();
    assert_eq!(s.weighted_sum, s.trace.weighted_sum(&weights), "{ctx}");
}

#[test]
fn prop_simulate_invariants_hold_for_random_assignments() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let jobs = random_jobs(&mut rng);
        let assignment: Vec<MachineId> = (0..jobs.len())
            .map(|_| MachineId::ALL[rng.below(3) as usize])
            .collect();
        let s = simulate(&jobs, &assignment);
        check_schedule_invariants(&jobs, &s, &format!("seed {seed}"));
    }
}

#[test]
fn prop_algorithm2_dominates_greedy_and_lower_bound() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xA5A5);
        let jobs = random_jobs(&mut rng);
        let params = SchedulerParams::default();
        let ours = schedule_jobs(&jobs, &params);
        check_schedule_invariants(&jobs, &ours, &format!("seed {seed}"));
        let greedy = simulate(&jobs, &greedy_assignment(&jobs));
        assert!(
            ours.weighted_sum <= greedy.weighted_sum,
            "seed {seed}: tabu {} worse than greedy {}",
            ours.weighted_sum,
            greedy.weighted_sum
        );
        assert!(
            ours.weighted_sum >= lower_bound(&jobs),
            "seed {seed}: beat the lower bound?!"
        );
    }
}

#[test]
fn prop_algorithm2_never_loses_to_fixed_strategies() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x5A5A);
        let jobs = random_jobs(&mut rng);
        let ours = schedule_jobs(&jobs, &SchedulerParams::default());
        for strat in
            [Strategy::AllCloud, Strategy::AllEdge, Strategy::AllDevice]
        {
            let base = simulate(&jobs, &strat.assignment(&jobs));
            assert!(
                ours.weighted_sum <= base.weighted_sum,
                "seed {seed}: lost to {strat:?} ({} vs {})",
                ours.weighted_sum,
                base.weighted_sum
            );
        }
    }
}

#[test]
fn prop_scaling_all_times_scales_objective() {
    // doubling every duration (incl. releases) doubles the objective
    for seed in 0..50 {
        let mut rng = Rng::new(seed ^ 0x1111);
        let jobs = random_jobs(&mut rng);
        let doubled: Vec<Job> = jobs
            .iter()
            .map(|j| Job {
                release: j.release * 2,
                weight: j.weight,
                proc_cloud: j.proc_cloud * 2,
                trans_cloud: j.trans_cloud * 2,
                proc_edge: j.proc_edge * 2,
                trans_edge: j.trans_edge * 2,
                proc_device: j.proc_device * 2,
            })
            .collect();
        let assignment: Vec<MachineId> = (0..jobs.len())
            .map(|_| MachineId::ALL[rng.below(3) as usize])
            .collect();
        let a = simulate(&jobs, &assignment);
        let b = simulate(&doubled, &assignment);
        assert_eq!(
            b.weighted_sum,
            a.weighted_sum * 2,
            "seed {seed}"
        );
    }
}

#[test]
fn prop_adding_a_job_never_reduces_others_response() {
    // monotonicity of contention on a shared machine
    for seed in 0..50 {
        let mut rng = Rng::new(seed ^ 0x2222);
        let mut jobs = random_jobs(&mut rng);
        let assignment = vec![MachineId::Edge; jobs.len()];
        let before = simulate(&jobs, &assignment);
        jobs.push(Job {
            release: 0,
            weight: 1,
            proc_cloud: 1,
            trans_cloud: 1,
            proc_edge: 5,
            trans_edge: 1,
            proc_device: 1,
        });
        let after = simulate(&jobs, &vec![MachineId::Edge; jobs.len()]);
        for e_before in &before.trace.entries {
            let e_after = after
                .trace
                .entries
                .iter()
                .find(|e| e.job == e_before.job)
                .unwrap();
            assert!(
                e_after.end >= e_before.end,
                "seed {seed}: job {} finished earlier with more load",
                e_before.job
            );
        }
    }
}

#[test]
fn prop_priority_weight_steers_the_optimizer() {
    // give one job an enormous weight: Algorithm 2's objective for that
    // job must be at least as good as with weight 1
    let base_jobs = paper_jobs();
    let params = SchedulerParams::default();
    for victim in 0..base_jobs.len() {
        let mut heavy = base_jobs.clone();
        heavy[victim].weight = 100;
        let s_heavy = schedule_jobs(&heavy, &params);
        let s_base = schedule_jobs(&base_jobs, &params);
        let resp = |s: &Schedule, j: usize| {
            s.trace.entries.iter().find(|e| e.job == j).unwrap().response()
        };
        assert!(
            resp(&s_heavy, victim) <= resp(&s_base, victim).max(
                // allow equality when the job was already optimal
                resp(&s_heavy, victim)
            ),
            "victim {victim}"
        );
        // the heavy job's response must be near its best possible
        let best = MachineId::ALL
            .iter()
            .map(|&m| heavy[victim].execution(m))
            .min()
            .unwrap();
        assert!(
            resp(&s_heavy, victim) <= best * 3,
            "victim {victim}: response {} vs best {best}",
            resp(&s_heavy, victim)
        );
    }
}

#[test]
fn prop_strategies_agree_on_singleton_jobs() {
    // with one job there is no contention: ours == per-job-optimal
    for seed in 0..50 {
        let mut rng = Rng::new(seed ^ 0x3333);
        let jobs = vec![random_jobs(&mut rng)[0]];
        let ours = evaluate_strategy(&jobs, Strategy::Ours);
        let opt = evaluate_strategy(&jobs, Strategy::PerJobOptimal);
        assert_eq!(
            ours.schedule.weighted_sum, opt.schedule.weighted_sum,
            "seed {seed}"
        );
    }
}
