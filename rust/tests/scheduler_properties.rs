//! Property-based tests on scheduler invariants.
//!
//! The offline build has no proptest crate, so random-input property
//! testing is driven by the in-tree deterministic RNG: 200 random job sets
//! per property, with the failing seed printed for reproduction
//! (substitution ledger, DESIGN.md §3).

use edgeward::data::Rng;
use edgeward::scenario::Objective;
use edgeward::scheduler::{
    apply_move, greedy_assignment, improve, improve_objective,
    lower_bound, objective_cost, objective_cost_delta, paper_jobs,
    prepare_delta, schedule_jobs_objective, schedule_lns_objective,
    simulate, Job, MachineId, MachineRef, Schedule, SchedulerParams,
    SimScratch, Strategy, Topology,
};

const CASES: u64 = 200;

/// Algorithm 2 under the paper objective (the pre-scenario
/// `schedule_jobs`).
fn schedule_jobs(
    jobs: &[Job],
    topo: &Topology,
    params: &SchedulerParams,
) -> Schedule {
    schedule_jobs_objective(jobs, topo, params, &Objective::WeightedSum)
}

/// Random job set in the paper's regime.
fn random_jobs(rng: &mut Rng) -> Vec<Job> {
    let n = 1 + rng.below(15) as usize;
    let mut release = 0;
    (0..n)
        .map(|_| {
            release += rng.below(6);
            Job {
                release,
                weight: 1 + rng.below(3) as u32,
                proc_cloud: 1 + rng.below(10),
                trans_cloud: 1 + rng.below(70),
                proc_edge: 1 + rng.below(15),
                trans_edge: 1 + rng.below(15),
                proc_device: 1 + rng.below(80),
            }
        })
        .collect()
}

/// Per-replica factors drawn from the grid the heterogeneous scenarios
/// exercise.
fn random_factors(rng: &mut Rng, k: usize) -> Vec<f64> {
    const FACTORS: [f64; 4] = [0.5, 1.0, 1.5, 2.0];
    (0..k).map(|_| FACTORS[rng.below(4) as usize]).collect()
}

/// Random topology with independent per-replica speed *and* link
/// factors — the worst case for any incremental-evaluation shortcut.
fn random_topology(rng: &mut Rng) -> Topology {
    let clouds = 1 + rng.below(2) as usize;
    let edges = 1 + rng.below(3) as usize;
    let cloud_speeds = random_factors(rng, clouds);
    let edge_speeds = random_factors(rng, edges);
    let cloud_links = random_factors(rng, clouds);
    let edge_links = random_factors(rng, edges);
    Topology::with_factors(
        clouds,
        edges,
        Some(cloud_speeds),
        Some(edge_speeds),
        Some(cloud_links),
        Some(edge_links),
    )
    .expect("grid factors are positive and finite")
}

/// All four objective families, including a multi-deadline rotation.
fn all_objectives() -> [Objective; 4] {
    [
        Objective::WeightedSum,
        Objective::UnweightedSum,
        Objective::Makespan,
        Objective::DeadlineMiss { deadlines: vec![20, 45] },
    ]
}

/// C1–C5 invariants of a finished schedule, for any topology.
fn check_schedule_invariants(jobs: &[Job], s: &Schedule, ctx: &str) {
    assert_eq!(s.assignment.len(), jobs.len(), "{ctx}: coverage");
    assert_eq!(s.trace.entries.len(), jobs.len(), "{ctx}: trace");

    // per-job invariants
    for e in &s.trace.entries {
        let j = &jobs[e.job];
        let m = s.assignment[e.job];
        assert!(s.topology.contains(m), "{ctx}: replica out of range");
        assert_eq!(e.machine, m, "{ctx}: machine mismatch");
        assert_eq!(e.release, j.release, "{ctx}");
        // C4: transmission starts at release and overlaps execution — the
        // job is available exactly transmission later, never blocked on
        // the machine being busy
        assert_eq!(
            e.available,
            j.release + j.transmission(m.class),
            "{ctx}"
        );
        assert!(e.start >= e.available, "{ctx}: start before data arrives");
        assert_eq!(
            e.end,
            e.start
                + s.topology
                    .scaled_processing(j.processing(m.class), m),
            "{ctx}: duration"
        );
        if m.class == MachineId::Device {
            assert_eq!(e.start, e.available, "{ctx}: device queued");
        }
    }

    // C1: exclusive machines never overlap, checked per *replica*
    for m in s.topology.shared_machines() {
        let mut slots: Vec<(u64, u64)> = s
            .trace
            .entries
            .iter()
            .filter(|e| e.machine == m)
            .map(|e| (e.start, e.end))
            .collect();
        slots.sort_unstable();
        for w in slots.windows(2) {
            assert!(w[0].1 <= w[1].0, "{ctx}: overlap on {m:?}: {w:?}");
        }
    }

    // objective consistency
    let weights: Vec<u32> = jobs.iter().map(|j| j.weight).collect();
    assert_eq!(s.weighted_sum, s.trace.weighted_sum(&weights), "{ctx}");
}

#[test]
fn prop_simulate_invariants_hold_for_random_assignments() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let jobs = random_jobs(&mut rng);
        let topo = Topology::paper();
        let machines = topo.machines();
        let assignment: Vec<MachineRef> = (0..jobs.len())
            .map(|_| machines[rng.below(machines.len() as u64) as usize])
            .collect();
        let s = simulate(&jobs, &topo, &assignment);
        check_schedule_invariants(&jobs, &s, &format!("seed {seed}"));
    }
}

#[test]
fn prop_algorithm2_dominates_greedy_and_lower_bound() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xA5A5);
        let jobs = random_jobs(&mut rng);
        let topo = Topology::paper();
        let params = SchedulerParams::default();
        let ours = schedule_jobs(&jobs, &topo, &params);
        check_schedule_invariants(&jobs, &ours, &format!("seed {seed}"));
        let greedy =
            simulate(&jobs, &topo, &greedy_assignment(&jobs, &topo));
        assert!(
            ours.weighted_sum <= greedy.weighted_sum,
            "seed {seed}: tabu {} worse than greedy {}",
            ours.weighted_sum,
            greedy.weighted_sum
        );
        assert!(
            ours.weighted_sum >= lower_bound(&jobs),
            "seed {seed}: beat the lower bound?!"
        );
    }
}

#[test]
fn prop_algorithm2_never_loses_to_fixed_strategies() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x5A5A);
        let jobs = random_jobs(&mut rng);
        let topo = Topology::paper();
        let ours = schedule_jobs(&jobs, &topo, &SchedulerParams::default());
        for strat in
            [Strategy::AllCloud, Strategy::AllEdge, Strategy::AllDevice]
        {
            let base =
                simulate(&jobs, &topo, &strat.assignment(&jobs, &topo));
            assert!(
                ours.weighted_sum <= base.weighted_sum,
                "seed {seed}: lost to {strat:?} ({} vs {})",
                ours.weighted_sum,
                base.weighted_sum
            );
        }
    }
}

/// Sweep the replica grid `clouds ∈ 1..=2, edges ∈ 1..=4`: every schedule
/// respects C1 (no overlap per replica) and C4 (transmission overlaps
/// execution; availability = release + transmission), and the weighted
/// cost is monotonically non-increasing as replicas are added.  The
/// monotone comparison warm-starts each topology from the previous
/// (smaller) topology's best assignment — feasible because replicas only
/// grow — so the property holds by construction of `improve` and catches
/// any regression where extra machines make the scheduler worse.
#[test]
fn prop_topology_sweep_monotone_and_feasible() {
    let params = SchedulerParams::default();
    let traces: Vec<(String, Vec<Job>)> = {
        let mut v = vec![("paper".to_string(), paper_jobs())];
        for seed in 0..10u64 {
            let mut rng = Rng::new(seed ^ 0xB0B0);
            v.push((format!("seed {seed}"), random_jobs(&mut rng)));
        }
        v
    };

    for (name, jobs) in &traces {
        for clouds in 1..=2usize {
            let mut prev: Option<Schedule> = None;
            for edges in 1..=4usize {
                let topo = Topology::new(clouds, edges);
                let mut best = schedule_jobs(jobs, &topo, &params);
                check_schedule_invariants(
                    jobs,
                    &best,
                    &format!("{name} {}", topo.label()),
                );
                if let Some(p) = &prev {
                    // the smaller topology's assignment stays feasible
                    let warm =
                        improve(jobs, &topo, p.assignment.clone(), &params);
                    check_schedule_invariants(
                        jobs,
                        &warm,
                        &format!("{name} warm {}", topo.label()),
                    );
                    if warm.weighted_sum < best.weighted_sum {
                        best = warm;
                    }
                    assert!(
                        best.weighted_sum <= p.weighted_sum,
                        "{name}: cost rose {} -> {} at {}",
                        p.weighted_sum,
                        best.weighted_sum,
                        topo.label()
                    );
                }
                prev = Some(best);
            }
        }
    }
}

/// Speeding up any single replica never worsens the *optimal* makespan
/// (ISSUE 4 satellite): `ceil(p / speed)` is non-increasing in `speed`
/// and the FCFS availability order is speed-independent, so every
/// assignment's completions — and hence the optimum over all
/// assignments — are monotone.  Checked against the exact
/// branch-and-bound on small random traces, for speed-ups of each
/// shared replica in turn.
#[test]
fn prop_speeding_up_a_replica_never_worsens_optimal_makespan() {
    use edgeward::scenario::solver;
    let exact = solver("exact").unwrap();
    let makespan_opt = |jobs: &[Job], topo: &Topology| -> u64 {
        let scenario = edgeward::scenario::Scenario::builder()
            .jobs(jobs.to_vec())
            .topology(topo.clone())
            .objective(Objective::Makespan)
            .build()
            .unwrap();
        let s = exact.solve(&scenario).unwrap();
        scenario.evaluate(&s)
    };
    for seed in 0..12u64 {
        let mut rng = Rng::new(seed ^ 0xFEED);
        let jobs: Vec<Job> =
            random_jobs(&mut rng).into_iter().take(6).collect();
        // 1 cloud + 2 edges, three shared replicas to speed up in turn
        let base_speeds = [1.0, 1.0, 1.0];
        let base = Topology::with_speeds(
            1,
            2,
            Some(vec![base_speeds[0]]),
            Some(vec![base_speeds[1], base_speeds[2]]),
        )
        .unwrap();
        let base_opt = makespan_opt(&jobs, &base);
        for bump in 0..3usize {
            for factor in [1.5, 2.0, 4.0] {
                let mut speeds = base_speeds;
                speeds[bump] = factor;
                let topo = Topology::with_speeds(
                    1,
                    2,
                    Some(vec![speeds[0]]),
                    Some(vec![speeds[1], speeds[2]]),
                )
                .unwrap();
                let opt = makespan_opt(&jobs, &topo);
                assert!(
                    opt <= base_opt,
                    "seed {seed}: speeding replica {bump} ×{factor} \
                     worsened optimal makespan {base_opt} -> {opt}"
                );
            }
        }
    }
}

/// Speeding up any single replica's *link* never worsens the optimal
/// makespan (ISSUE 5 satellite): `ceil(t / link)` is non-increasing in
/// `link`, so every job's availability on that replica only moves
/// earlier — and although earlier availability can reshuffle the FCFS
/// serving order for a *fixed* assignment, the optimum over all
/// assignments can always route around a reshuffle.  Checked against
/// the exact branch-and-bound on small random traces, for link-ups of
/// each shared replica in turn — the link mirror of
/// `prop_speeding_up_a_replica_never_worsens_optimal_makespan`.
#[test]
fn prop_speeding_up_a_link_never_worsens_optimal_makespan() {
    use edgeward::scenario::solver;
    let exact = solver("exact").unwrap();
    let makespan_opt = |jobs: &[Job], topo: &Topology| -> u64 {
        let scenario = edgeward::scenario::Scenario::builder()
            .jobs(jobs.to_vec())
            .topology(topo.clone())
            .objective(Objective::Makespan)
            .build()
            .unwrap();
        let s = exact.solve(&scenario).unwrap();
        scenario.evaluate(&s)
    };
    for seed in 0..12u64 {
        let mut rng = Rng::new(seed ^ 0xF1ED);
        let jobs: Vec<Job> =
            random_jobs(&mut rng).into_iter().take(6).collect();
        // 1 cloud + 2 edges, three shared replicas to re-link in turn
        let base = Topology::new(1, 2);
        let base_opt = makespan_opt(&jobs, &base);
        for bump in 0..3usize {
            for factor in [1.5, 2.0, 4.0] {
                let mut links = [1.0, 1.0, 1.0];
                links[bump] = factor;
                let topo = Topology::with_links(
                    1,
                    2,
                    Some(vec![links[0]]),
                    Some(vec![links[1], links[2]]),
                )
                .unwrap();
                let opt = makespan_opt(&jobs, &topo);
                assert!(
                    opt <= base_opt,
                    "seed {seed}: speeding replica {bump}'s link \
                     ×{factor} worsened optimal makespan {base_opt} -> \
                     {opt}"
                );
            }
        }
    }
}

/// Unit-speed replicas of a class are interchangeable: permuting which
/// replica a fixed all-edge assignment uses never changes the objective.
#[test]
fn prop_replica_symmetry() {
    for seed in 0..50 {
        let mut rng = Rng::new(seed ^ 0x6666);
        let jobs = random_jobs(&mut rng);
        let topo = Topology::new(1, 3);
        let costs: Vec<u64> = (0..3)
            .map(|r| {
                simulate(
                    &jobs,
                    &topo,
                    &vec![MachineRef::edge(r); jobs.len()],
                )
                .weighted_sum
            })
            .collect();
        assert!(
            costs.windows(2).all(|w| w[0] == w[1]),
            "seed {seed}: replica asymmetry {costs:?}"
        );
    }
}

#[test]
fn prop_scaling_all_times_scales_objective() {
    // doubling every duration (incl. releases) doubles the objective
    for seed in 0..50 {
        let mut rng = Rng::new(seed ^ 0x1111);
        let jobs = random_jobs(&mut rng);
        let doubled: Vec<Job> = jobs
            .iter()
            .map(|j| Job {
                release: j.release * 2,
                weight: j.weight,
                proc_cloud: j.proc_cloud * 2,
                trans_cloud: j.trans_cloud * 2,
                proc_edge: j.proc_edge * 2,
                trans_edge: j.trans_edge * 2,
                proc_device: j.proc_device * 2,
            })
            .collect();
        let topo = Topology::paper();
        let machines = topo.machines();
        let assignment: Vec<MachineRef> = (0..jobs.len())
            .map(|_| machines[rng.below(machines.len() as u64) as usize])
            .collect();
        let a = simulate(&jobs, &topo, &assignment);
        let b = simulate(&doubled, &topo, &assignment);
        assert_eq!(b.weighted_sum, a.weighted_sum * 2, "seed {seed}");
    }
}

#[test]
fn prop_adding_a_job_never_reduces_others_response() {
    // monotonicity of contention on a shared machine
    for seed in 0..50 {
        let mut rng = Rng::new(seed ^ 0x2222);
        let mut jobs = random_jobs(&mut rng);
        let topo = Topology::paper();
        let assignment = vec![MachineRef::edge(0); jobs.len()];
        let before = simulate(&jobs, &topo, &assignment);
        jobs.push(Job {
            release: 0,
            weight: 1,
            proc_cloud: 1,
            trans_cloud: 1,
            proc_edge: 5,
            trans_edge: 1,
            proc_device: 1,
        });
        let after = simulate(
            &jobs,
            &topo,
            &vec![MachineRef::edge(0); jobs.len()],
        );
        for e_before in &before.trace.entries {
            let e_after = after
                .trace
                .entries
                .iter()
                .find(|e| e.job == e_before.job)
                .unwrap();
            assert!(
                e_after.end >= e_before.end,
                "seed {seed}: job {} finished earlier with more load",
                e_before.job
            );
        }
    }
}

#[test]
fn prop_priority_weight_steers_the_optimizer() {
    // give one job an enormous weight: Algorithm 2's objective for that
    // job must be at least as good as with weight 1
    let base_jobs = paper_jobs();
    let topo = Topology::paper();
    let params = SchedulerParams::default();
    for victim in 0..base_jobs.len() {
        let mut heavy = base_jobs.clone();
        heavy[victim].weight = 100;
        let s_heavy = schedule_jobs(&heavy, &topo, &params);
        let s_base = schedule_jobs(&base_jobs, &topo, &params);
        let resp = |s: &Schedule, j: usize| {
            s.trace.entries.iter().find(|e| e.job == j).unwrap().response()
        };
        assert!(
            resp(&s_heavy, victim) <= resp(&s_base, victim).max(
                // allow equality when the job was already optimal
                resp(&s_heavy, victim)
            ),
            "victim {victim}"
        );
        // the heavy job's response must be near its best possible
        let best = MachineId::ALL
            .iter()
            .map(|&m| heavy[victim].execution(m))
            .min()
            .unwrap();
        assert!(
            resp(&s_heavy, victim) <= best * 3,
            "victim {victim}: response {} vs best {best}",
            resp(&s_heavy, victim)
        );
    }
}

#[test]
fn prop_strategies_agree_on_singleton_jobs() {
    // with one job there is no contention: ours == per-job-optimal
    let topo = Topology::paper();
    for seed in 0..50 {
        let mut rng = Rng::new(seed ^ 0x3333);
        let jobs = vec![random_jobs(&mut rng)[0]];
        let ours =
            schedule_jobs(&jobs, &topo, &SchedulerParams::default());
        let opt = simulate(
            &jobs,
            &topo,
            &Strategy::PerJobOptimal.assignment(&jobs, &topo),
        );
        assert_eq!(
            ours.weighted_sum, opt.weighted_sum,
            "seed {seed}"
        );
    }
}

/// The incremental move evaluator is an *exact* mirror of the full
/// re-simulation: over random heterogeneous topologies, every objective,
/// and random move sequences, each quoted `objective_cost_delta` equals
/// a fresh `objective_cost` of the moved assignment, and each committed
/// `apply_move` equals its quote — so the delta-priced tabu search
/// selects bit-for-bit the same moves the full-recompute search did.
#[test]
fn prop_delta_cost_matches_full_after_every_move() {
    let mut probe_scratch = SimScratch::default();
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed ^ 0xDE17A);
        let topo = random_topology(&mut rng);
        let machines = topo.machines();
        let jobs = random_jobs(&mut rng);
        for objective in all_objectives() {
            let mut assignment: Vec<MachineRef> = (0..jobs.len())
                .map(|_| {
                    machines[rng.below(machines.len() as u64) as usize]
                })
                .collect();
            let mut scratch = SimScratch::default();
            let total = prepare_delta(
                &jobs,
                &topo,
                &assignment,
                &objective,
                &mut scratch,
            );
            assert_eq!(
                total,
                objective_cost(
                    &jobs,
                    &topo,
                    &assignment,
                    &objective,
                    &mut probe_scratch
                ),
                "seed {seed}: prepare mismatch under {objective}"
            );
            for step in 0..20 {
                let job = rng.below(jobs.len() as u64) as usize;
                let to =
                    machines[rng.below(machines.len() as u64) as usize];
                let quote = objective_cost_delta(
                    &jobs, &topo, &assignment, &objective, &scratch,
                    job, to,
                );
                let mut probe = assignment.clone();
                probe[job] = to;
                let fresh = objective_cost(
                    &jobs,
                    &topo,
                    &probe,
                    &objective,
                    &mut probe_scratch,
                );
                assert_eq!(
                    quote, fresh,
                    "seed {seed} step {step}: delta quote diverged \
                     from full re-simulation under {objective}"
                );
                let committed = apply_move(
                    &jobs,
                    &topo,
                    &mut assignment,
                    &objective,
                    &mut scratch,
                    job,
                    to,
                );
                assert_eq!(
                    committed, quote,
                    "seed {seed} step {step}: commit != quote"
                );
            }
        }
    }
}

/// The LNS destroy/repair tier accepts a repaired plan only when it
/// strictly improves, starting from the greedy seed — so it is never
/// worse than greedy, on any topology, under any objective.
#[test]
fn prop_lns_never_worse_than_greedy_for_any_objective() {
    let mut scratch = SimScratch::default();
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0x715A);
        let topo = random_topology(&mut rng);
        let jobs = random_jobs(&mut rng);
        for objective in all_objectives() {
            let greedy = objective_cost(
                &jobs,
                &topo,
                &greedy_assignment(&jobs, &topo),
                &objective,
                &mut scratch,
            );
            let s = schedule_lns_objective(&jobs, &topo, &objective, seed);
            check_schedule_invariants(
                &jobs,
                &s,
                &format!("lns seed {seed}"),
            );
            assert!(
                objective.evaluate(&jobs, &s.trace) <= greedy,
                "seed {seed}: lns lost to its greedy seed under \
                 {objective}"
            );
        }
    }
}

/// The warm-started replica sweep is monotone for *every* objective, not
/// just eq. 5: adding an edge replica never worsens the best makespan or
/// deadline-miss count (the smaller topology's assignment stays feasible
/// and `improve_objective` returns the best assignment ever seen).
#[test]
fn prop_makespan_and_deadline_objectives_monotone_in_replicas() {
    let params = SchedulerParams::default();
    let objectives = [
        Objective::Makespan,
        Objective::DeadlineMiss { deadlines: vec![25] },
        Objective::DeadlineMiss { deadlines: vec![12, 30, 60] },
    ];
    let traces: Vec<(String, Vec<Job>)> = {
        let mut v = vec![("paper".to_string(), paper_jobs())];
        for seed in 0..6u64 {
            let mut rng = Rng::new(seed ^ 0xD00D);
            v.push((format!("seed {seed}"), random_jobs(&mut rng)));
        }
        v
    };
    for obj in &objectives {
        for (name, jobs) in &traces {
            let mut prev: Option<(Vec<MachineRef>, u64)> = None;
            for edges in 1..=4usize {
                let topo = Topology::new(1, edges);
                let fresh =
                    schedule_jobs_objective(jobs, &topo, &params, obj);
                let mut best_val = obj.evaluate(jobs, &fresh.trace);
                let mut best_assignment = fresh.assignment;
                if let Some((prev_assignment, prev_val)) = &prev {
                    // warm start: the smaller topology's solution is
                    // still feasible, so the best only improves
                    let warm = improve_objective(
                        jobs,
                        &topo,
                        prev_assignment.clone(),
                        &params,
                        obj,
                    );
                    let warm_val = obj.evaluate(jobs, &warm.trace);
                    if warm_val < best_val {
                        best_val = warm_val;
                        best_assignment = warm.assignment;
                    }
                    assert!(
                        best_val <= *prev_val,
                        "{name} [{}]: {} rose {prev_val} -> {best_val} \
                         at {}",
                        obj.key(),
                        obj.label(),
                        topo.label()
                    );
                }
                prev = Some((best_assignment, best_val));
            }
        }
    }
}
