//! Equivalence regression: the unified topology-parameterized scheduler
//! at `Topology::paper()` must reproduce the pre-topology scheduler
//! bit-for-bit.
//!
//! The `reference` module below is a frozen copy of the seed scheduler's
//! semantics — hard-coded cloud/edge scalars, `MachineId`-only
//! assignments, the cloud-first tie-breaks — kept as the golden oracle.
//! Every test drives both implementations over the paper trace and random
//! job sets and asserts identical weighted sums, traces, greedy
//! assignments, and tabu outcomes, plus the recorded Table VII golden
//! numbers (416/100, 291, 366/94).

// this suite deliberately exercises the deprecated single-objective shims:
// their whole contract is staying bit-for-bit with the seed scheduler
#![allow(deprecated)]

use edgeward::data::Rng;
use edgeward::scheduler::{
    greedy_assignment, paper_jobs, schedule_jobs, simulate, weighted_cost,
    Job, MachineId, MachineRef, SchedulerParams, SimScratch, Topology,
};

/// The seed scheduler, frozen: one cloud scalar, one edge scalar, moves
/// over `MachineId::ALL`.  Do not "improve" this module — its whole value
/// is staying identical to the pre-refactor behavior.
mod reference {
    use edgeward::scheduler::{Job, MachineId, SchedulerParams};
    use edgeward::simulation::MachineTimeline;

    pub fn weighted_cost(jobs: &[Job], assignment: &[MachineId]) -> u64 {
        let mut order: Vec<usize> = (0..jobs.len()).collect();
        order.sort_unstable_by_key(|&i| {
            (
                jobs[i].release + jobs[i].transmission(assignment[i]),
                jobs[i].release,
                i,
            )
        });
        let (mut cloud_free, mut edge_free) = (0u64, 0u64);
        let mut sum = 0u64;
        for &i in &order {
            let j = &jobs[i];
            let m = assignment[i];
            let avail = j.release + j.transmission(m);
            let p = j.processing(m);
            let end = match m {
                MachineId::Cloud => {
                    let start = avail.max(cloud_free);
                    cloud_free = start + p;
                    cloud_free
                }
                MachineId::Edge => {
                    let start = avail.max(edge_free);
                    edge_free = start + p;
                    edge_free
                }
                MachineId::Device => avail + p,
            };
            sum += j.weight as u64 * (end - j.release);
        }
        sum
    }

    /// (start, end) per job, in job order — the trace shape.
    pub fn simulate_slots(
        jobs: &[Job],
        assignment: &[MachineId],
    ) -> Vec<(u64, u64)> {
        let mut order: Vec<usize> = (0..jobs.len()).collect();
        let avail =
            |i: usize| jobs[i].release + jobs[i].transmission(assignment[i]);
        order.sort_by_key(|&i| (avail(i), jobs[i].release, i));
        let mut cloud = MachineTimeline::new();
        let mut edge = MachineTimeline::new();
        let mut slots = vec![(0u64, 0u64); jobs.len()];
        for &i in &order {
            let a = avail(i);
            let p = jobs[i].processing(assignment[i]);
            slots[i] = match assignment[i] {
                MachineId::Cloud => cloud.schedule(a, p),
                MachineId::Edge => edge.schedule(a, p),
                MachineId::Device => (a, a + p),
            };
        }
        slots
    }

    pub fn greedy_assignment(jobs: &[Job]) -> Vec<MachineId> {
        let mut order: Vec<usize> = (0..jobs.len()).collect();
        order.sort_by_key(|&i| {
            (jobs[i].release, std::cmp::Reverse(jobs[i].weight), i)
        });
        let mut cloud = MachineTimeline::new();
        let mut edge = MachineTimeline::new();
        let mut assignment = vec![MachineId::Device; jobs.len()];
        for &i in &order {
            let j = &jobs[i];
            let avail_c = j.release + j.trans_cloud;
            let avail_e = j.release + j.trans_edge;
            let end_cloud = cloud.peek(avail_c, j.proc_cloud).1;
            let end_edge = edge.peek(avail_e, j.proc_edge).1;
            let end_device = j.release + j.proc_device;
            let (mut best_m, mut best_end) = (MachineId::Cloud, end_cloud);
            if end_edge < best_end {
                best_m = MachineId::Edge;
                best_end = end_edge;
            }
            if end_device < best_end {
                best_m = MachineId::Device;
            }
            assignment[i] = best_m;
            match best_m {
                MachineId::Cloud => {
                    cloud.schedule(avail_c, j.proc_cloud);
                }
                MachineId::Edge => {
                    edge.schedule(avail_e, j.proc_edge);
                }
                MachineId::Device => {}
            }
        }
        assignment
    }

    pub fn schedule_jobs(
        jobs: &[Job],
        params: &SchedulerParams,
    ) -> (Vec<MachineId>, u64) {
        let mut current = greedy_assignment(jobs);
        let mut best_assignment = current.clone();
        let mut best_cost = weighted_cost(jobs, &current);
        let mut tabu: std::collections::HashMap<(usize, MachineId), usize> =
            std::collections::HashMap::new();
        let mut stall = 0usize;
        for iter in 0..params.max_iters {
            let mut best_move: Option<(usize, MachineId, u64)> = None;
            for i in 0..jobs.len() {
                let old_m = current[i];
                for m in MachineId::ALL {
                    if m == old_m {
                        continue;
                    }
                    let forbidden = tabu
                        .get(&(i, m))
                        .map_or(false, |&until| iter < until);
                    current[i] = m;
                    let cost = weighted_cost(jobs, &current);
                    current[i] = old_m;
                    if forbidden && cost >= best_cost {
                        continue;
                    }
                    if best_move.map_or(true, |(_, _, c)| cost < c) {
                        best_move = Some((i, m, cost));
                    }
                }
            }
            let Some((i, m, cost)) = best_move else { break };
            let old_m = current[i];
            current[i] = m;
            tabu.insert((i, old_m), iter + params.tenure);
            if cost < best_cost {
                best_cost = cost;
                best_assignment = current.clone();
                stall = 0;
            } else {
                stall += 1;
                if stall >= params.patience {
                    break;
                }
            }
        }
        let cost = weighted_cost(jobs, &best_assignment);
        (best_assignment, cost)
    }
}

fn random_jobs(rng: &mut Rng) -> Vec<Job> {
    let n = 1 + rng.below(12) as usize;
    let mut release = 0;
    (0..n)
        .map(|_| {
            release += rng.below(6);
            Job {
                release,
                weight: 1 + rng.below(3) as u32,
                proc_cloud: 1 + rng.below(10),
                trans_cloud: 1 + rng.below(70),
                proc_edge: 1 + rng.below(15),
                trans_edge: 1 + rng.below(15),
                proc_device: 1 + rng.below(80),
            }
        })
        .collect()
}

/// Lift a class-only assignment into the paper topology (replica 0).
fn lift(assignment: &[MachineId]) -> Vec<MachineRef> {
    assignment
        .iter()
        .map(|&class| MachineRef { class, replica: 0 })
        .collect()
}

#[test]
fn golden_table_vii_baselines() {
    // golden values recorded from the seed scheduler before the refactor
    let jobs = paper_jobs();
    let topo = Topology::paper();
    let cloud = simulate(&jobs, &topo, &vec![MachineRef::cloud(0); 10]);
    assert_eq!(cloud.unweighted_sum(), 416);
    assert_eq!(cloud.last_completion(), 100);
    let edge = simulate(&jobs, &topo, &vec![MachineRef::edge(0); 10]);
    assert_eq!(edge.unweighted_sum(), 291);
    let device = simulate(&jobs, &topo, &vec![MachineRef::DEVICE; 10]);
    assert_eq!(device.unweighted_sum(), 366);
    assert_eq!(device.last_completion(), 94);
}

#[test]
fn simulate_matches_reference_on_random_assignments() {
    let mut scratch = SimScratch::default();
    let topo = Topology::paper();
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed ^ 0xEEE1);
        let jobs = random_jobs(&mut rng);
        let classes: Vec<MachineId> = (0..jobs.len())
            .map(|_| MachineId::ALL[rng.below(3) as usize])
            .collect();
        let unified = simulate(&jobs, &topo, &lift(&classes));
        let ref_cost = reference::weighted_cost(&jobs, &classes);
        assert_eq!(unified.weighted_sum, ref_cost, "seed {seed}");
        let fast =
            weighted_cost(&jobs, &topo, &lift(&classes), &mut scratch);
        assert_eq!(fast, ref_cost, "seed {seed} (scratch path)");
        // full trace equivalence, not just the objective
        let ref_slots = reference::simulate_slots(&jobs, &classes);
        for e in &unified.trace.entries {
            assert_eq!(
                (e.start, e.end),
                ref_slots[e.job],
                "seed {seed} job {}",
                e.job
            );
        }
    }
}

#[test]
fn greedy_matches_reference() {
    let topo = Topology::paper();
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed ^ 0xEEE2);
        let jobs = random_jobs(&mut rng);
        let unified = greedy_assignment(&jobs, &topo);
        let golden = reference::greedy_assignment(&jobs);
        assert_eq!(unified, lift(&golden), "seed {seed}");
    }
    // and on the paper trace
    let jobs = paper_jobs();
    assert_eq!(
        greedy_assignment(&jobs, &topo),
        lift(&reference::greedy_assignment(&jobs))
    );
}

#[test]
fn tabu_matches_reference() {
    let topo = Topology::paper();
    let params = SchedulerParams::default();
    // the paper trace: identical assignment and objective
    let jobs = paper_jobs();
    let unified = schedule_jobs(&jobs, &topo, &params);
    let (ref_assignment, ref_cost) =
        reference::schedule_jobs(&jobs, &params);
    assert_eq!(unified.assignment, lift(&ref_assignment));
    assert_eq!(unified.weighted_sum, ref_cost);

    // random traces (fewer cases: the reference tabu is O(n² · iters))
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed ^ 0xEEE3);
        let jobs = random_jobs(&mut rng);
        let unified = schedule_jobs(&jobs, &topo, &params);
        let (ref_assignment, ref_cost) =
            reference::schedule_jobs(&jobs, &params);
        assert_eq!(
            unified.assignment,
            lift(&ref_assignment),
            "seed {seed}"
        );
        assert_eq!(unified.weighted_sum, ref_cost, "seed {seed}");
    }
}

/// ISSUE 4 satellite: an *explicit* all-1.0 speed vector is the same
/// topology as no speed vector at all — the whole pre-refactor test
/// battery above must hold verbatim through the explicit-speeds
/// constructor.  (Constructors canonicalize all-1.0 to the homogeneous
/// form, so equality is structural, and the simulate/greedy/tabu runs
/// below prove the scaled-processing path is the identity at 1.0.)
#[test]
fn explicit_unit_speeds_match_reference_bit_for_bit() {
    let topo = Topology::with_speeds(
        1,
        1,
        Some(vec![1.0]),
        Some(vec![1.0]),
    )
    .unwrap();
    assert_eq!(topo, Topology::paper());
    assert!(topo.is_paper());

    let params = SchedulerParams::default();
    let mut scratch = SimScratch::default();
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed ^ 0x0E0E);
        let jobs = random_jobs(&mut rng);
        let classes: Vec<MachineId> = (0..jobs.len())
            .map(|_| MachineId::ALL[rng.below(3) as usize])
            .collect();
        // simulate + weighted_cost against the frozen seed scheduler
        let unified = simulate(&jobs, &topo, &lift(&classes));
        assert_eq!(
            unified.weighted_sum,
            reference::weighted_cost(&jobs, &classes),
            "seed {seed}"
        );
        assert_eq!(
            weighted_cost(&jobs, &topo, &lift(&classes), &mut scratch),
            reference::weighted_cost(&jobs, &classes),
            "seed {seed}"
        );
        // greedy + tabu against the frozen seed scheduler
        assert_eq!(
            greedy_assignment(&jobs, &topo),
            lift(&reference::greedy_assignment(&jobs)),
            "seed {seed}"
        );
        if seed < 15 {
            let unified = schedule_jobs(&jobs, &topo, &params);
            let (ref_assignment, ref_cost) =
                reference::schedule_jobs(&jobs, &params);
            assert_eq!(
                unified.assignment,
                lift(&ref_assignment),
                "seed {seed}"
            );
            assert_eq!(unified.weighted_sum, ref_cost, "seed {seed}");
        }
    }
}

/// ISSUE 5 satellite: an *explicit* all-1.0 link vector is the same
/// topology as no link vector at all — the frozen seed-scheduler battery
/// must hold verbatim through the explicit-links constructor, proving
/// the scaled-transmission path is the identity at 1.0.
#[test]
fn explicit_unit_links_match_reference_bit_for_bit() {
    let topo = Topology::with_links(
        1,
        1,
        Some(vec![1.0]),
        Some(vec![1.0]),
    )
    .unwrap();
    assert_eq!(topo, Topology::paper());
    assert!(topo.is_paper());

    let params = SchedulerParams::default();
    let mut scratch = SimScratch::default();
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed ^ 0x1E0E);
        let jobs = random_jobs(&mut rng);
        let classes: Vec<MachineId> = (0..jobs.len())
            .map(|_| MachineId::ALL[rng.below(3) as usize])
            .collect();
        // simulate + weighted_cost against the frozen seed scheduler
        let unified = simulate(&jobs, &topo, &lift(&classes));
        assert_eq!(
            unified.weighted_sum,
            reference::weighted_cost(&jobs, &classes),
            "seed {seed}"
        );
        assert_eq!(
            weighted_cost(&jobs, &topo, &lift(&classes), &mut scratch),
            reference::weighted_cost(&jobs, &classes),
            "seed {seed}"
        );
        // full trace equivalence, not just the objective
        let ref_slots = reference::simulate_slots(&jobs, &classes);
        for e in &unified.trace.entries {
            assert_eq!(
                (e.start, e.end),
                ref_slots[e.job],
                "seed {seed} job {}",
                e.job
            );
        }
        // greedy + tabu against the frozen seed scheduler
        assert_eq!(
            greedy_assignment(&jobs, &topo),
            lift(&reference::greedy_assignment(&jobs)),
            "seed {seed}"
        );
        if seed < 15 {
            let unified = schedule_jobs(&jobs, &topo, &params);
            let (ref_assignment, ref_cost) =
                reference::schedule_jobs(&jobs, &params);
            assert_eq!(
                unified.assignment,
                lift(&ref_assignment),
                "seed {seed}"
            );
            assert_eq!(unified.weighted_sum, ref_cost, "seed {seed}");
        }
    }
}

/// Mixed explicit unit factors (speeds *and* links spelled out as 1.0)
/// still canonicalize to the paper topology and reproduce the golden
/// Table VII rows.
#[test]
fn explicit_unit_factors_keep_table_vii_goldens() {
    let topo = Topology::with_factors(
        1,
        1,
        Some(vec![1.0]),
        Some(vec![1.0]),
        Some(vec![1.0]),
        Some(vec![1.0]),
    )
    .unwrap();
    assert_eq!(topo, Topology::paper());
    let jobs = paper_jobs();
    let cloud = simulate(&jobs, &topo, &vec![MachineRef::cloud(0); 10]);
    assert_eq!(cloud.unweighted_sum(), 416);
    assert_eq!(cloud.last_completion(), 100);
    let edge = simulate(&jobs, &topo, &vec![MachineRef::edge(0); 10]);
    assert_eq!(edge.unweighted_sum(), 291);
    let device = simulate(&jobs, &topo, &vec![MachineRef::DEVICE; 10]);
    assert_eq!(device.unweighted_sum(), 366);
    assert_eq!(device.last_completion(), 94);
}

#[test]
fn single_allocation_classes_unchanged() {
    // the single-job argmin (Algorithm 1's scheduling analogue) is a
    // class-level decision and must not shift under the topology API
    for (i, j) in paper_jobs().iter().enumerate() {
        let topo = Topology::paper();
        let s = schedule_jobs(&[*j], &topo, &SchedulerParams::default());
        assert_eq!(
            s.assignment[0].class,
            j.optimal_machine(),
            "paper job {}",
            i + 1
        );
    }
}
