//! Integration: the PJRT runtime against the real AOT artifacts.
//!
//! Requires `make artifacts` (skips with a message otherwise — CI runs
//! artifacts first via the Makefile).

use edgeward::data::EpisodeGenerator;
use edgeward::runtime::InferenceRuntime;
use edgeward::workload::Application;

fn runtime() -> Option<InferenceRuntime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(InferenceRuntime::open("artifacts").expect("open artifacts"))
}

#[test]
fn manifest_covers_all_apps() {
    let Some(rt) = runtime() else { return };
    for app in Application::ALL {
        let sizes = rt.batch_sizes(app);
        assert!(!sizes.is_empty(), "{app} missing from manifest");
        assert!(sizes.contains(&1), "{app} needs a batch-1 variant");
    }
}

#[test]
fn infer_all_apps_batch1() {
    let Some(rt) = runtime() else { return };
    let mut gen = EpisodeGenerator::new(1);
    for app in Application::ALL {
        let ep = gen.episode(app);
        let out = rt.infer(app, 1, &ep.features).expect("infer");
        assert_eq!(out.probs.len(), app.output_dim());
        for &p in &out.probs {
            assert!(p.is_finite() && (0.0..=1.0).contains(&p), "{app}: {p}");
        }
    }
}

#[test]
fn inference_deterministic() {
    let Some(rt) = runtime() else { return };
    let mut gen = EpisodeGenerator::new(2);
    let app = Application::Breath;
    let ep = gen.episode(app);
    let a = rt.infer(app, 1, &ep.features).unwrap();
    let b = rt.infer(app, 1, &ep.features).unwrap();
    assert_eq!(a.probs, b.probs);
}

#[test]
fn batched_rows_match_singles() {
    // batching must not change per-row numerics (same weights, same rows)
    let Some(rt) = runtime() else { return };
    let app = Application::Mortality;
    let mut gen = EpisodeGenerator::new(3);
    let rows = 8;
    let input = gen.batch(app, rows);
    let batched = rt.infer(app, rows, &input).unwrap();

    let row_len = app.seq_len() * app.input_dim();
    for r in 0..rows {
        let single = rt
            .infer(app, 1, &input[r * row_len..(r + 1) * row_len])
            .unwrap();
        for (x, y) in single.probs.iter().zip(batched.row(r)) {
            assert!(
                (x - y).abs() < 1e-5,
                "row {r}: batched {y} vs single {x}"
            );
        }
    }
}

#[test]
fn infer_rows_splits_oversized_batches() {
    let Some(rt) = runtime() else { return };
    let app = Application::Mortality;
    let mut gen = EpisodeGenerator::new(4);
    let rows = 50; // > max compiled batch (32)
    let input = gen.batch(app, rows);
    let out = rt.infer_rows(app, rows, &input).unwrap();
    assert_eq!(out.probs.len(), rows * app.output_dim());
    // spot-check a row against a single call
    let row_len = app.seq_len() * app.input_dim();
    let idx = 40;
    let single = rt
        .infer(app, 1, &input[idx * row_len..(idx + 1) * row_len])
        .unwrap();
    assert!((single.probs[0] - out.row(idx)[0]).abs() < 1e-5);
}

#[test]
fn shape_mismatch_rejected() {
    let Some(rt) = runtime() else { return };
    let err = rt.infer(Application::Breath, 1, &[0.0; 7]).unwrap_err();
    assert!(err.to_string().contains("shape"), "{err}");
}

#[test]
fn padding_rows_are_ignored() {
    // zero-padding the tail must not affect real rows' outputs
    let Some(rt) = runtime() else { return };
    let app = Application::Phenotype;
    let mut gen = EpisodeGenerator::new(5);
    let row = gen.episode(app).features;
    let row_len = app.seq_len() * app.input_dim();
    let mut padded = row.clone();
    padded.resize(8 * row_len, 0.0);
    let out8 = rt.infer(app, 8, &padded).unwrap();
    let out1 = rt.infer(app, 1, &row).unwrap();
    for (a, b) in out1.probs.iter().zip(out8.row(0)) {
        assert!((a - b).abs() < 1e-5);
    }
}
