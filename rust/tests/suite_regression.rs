//! Scenario-suite regression harness, end to end: the committed corpus
//! under `scenarios/` runs clean against the committed goldens under
//! `baselines/`, the paper-trace row reproduces Table VII bit-for-bit,
//! results serialize byte-identically across runs, and bless/check
//! round-trips detect exactly the mutations they should.

use std::path::{Path, PathBuf};

use edgeward::scenario::Arrival;
use edgeward::scheduler::Job;
use edgeward::suite::{self, CellStatus, Suite, SuiteConfig, Verdict};

/// The committed corpus/goldens live at the repository root.  Cargo runs
/// integration tests from the package root, whose location relative to
/// the repository root depends on where the build harness put the
/// manifest — probe both.
fn repo_path(name: &str) -> PathBuf {
    for base in ["..", "."] {
        let p = Path::new(base).join(name);
        if p.is_dir() {
            return p;
        }
    }
    panic!(
        "committed {name}/ directory not found relative to {:?}",
        std::env::current_dir()
    )
}

fn seed7() -> SuiteConfig {
    SuiteConfig {
        seeds: vec![7],
        ..SuiteConfig::default()
    }
}

fn run_corpus() -> edgeward::suite::SuiteResult {
    Suite::discover(repo_path("scenarios"), seed7())
        .unwrap_or_else(|e| panic!("discovering scenarios/: {e}"))
        .run()
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("edgeward_sreg_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn committed_corpus_covers_the_required_scenarios() {
    let suite = Suite::discover(repo_path("scenarios"), seed7())
        .unwrap_or_else(|e| panic!("discovering scenarios/: {e}"));
    assert!(
        suite.scenarios.len() >= 8,
        "corpus must hold at least 8 scenarios, found {}",
        suite.scenarios.len()
    );
    let arrivals: Vec<&str> = suite
        .scenarios
        .iter()
        .filter_map(|s| s.scenario.arrival.as_ref().map(|a| a.key()))
        .collect();
    for required in [
        "paper-trace",
        "poisson-ward",
        "code-blue-surge",
        "diurnal-ward",
    ] {
        assert!(
            arrivals.contains(&required),
            "corpus is missing a {required} scenario: {arrivals:?}"
        );
    }
    // objective diversity: the matrix re-ranks solvers under these
    let objectives: Vec<&str> = suite
        .scenarios
        .iter()
        .map(|s| s.scenario.objective.key())
        .collect();
    for required in ["weighted-sum", "makespan", "deadline-miss"] {
        assert!(
            objectives.contains(&required),
            "corpus is missing a {required} scenario"
        );
    }
}

#[test]
fn committed_corpus_includes_heterogeneous_topologies() {
    let suite = Suite::discover(repo_path("scenarios"), seed7())
        .unwrap_or_else(|e| panic!("discovering scenarios/: {e}"));
    let hetero: Vec<&str> = suite
        .scenarios
        .iter()
        .filter(|s| !s.scenario.topology.is_homogeneous())
        .map(|s| s.stem.as_str())
        .collect();
    assert!(
        hetero.len() >= 4,
        "corpus must pin at least 4 heterogeneous-topology scenarios \
         (2 speed + 2 link), found {hetero:?}"
    );
    // ISSUE 5: at least two scenarios exercise *link* heterogeneity
    let linked: Vec<&str> = suite
        .scenarios
        .iter()
        .filter(|s| {
            let t = &s.scenario.topology;
            t.cloud_links()
                .into_iter()
                .chain(t.edge_links())
                .any(|l| l != 1.0)
        })
        .map(|s| s.stem.as_str())
        .collect();
    assert!(
        linked.len() >= 2,
        "corpus must pin at least 2 link-heterogeneous scenarios, \
         found {linked:?}"
    );
}

/// ISSUE 4/5 satellite: spelling every committed scenario's speed *and
/// link* factors out as explicit 1.0 vectors must reproduce
/// `baselines/*.json` byte-for-byte — the homogeneous corpus cannot
/// tell the difference between "no factors" and "all factors 1.0".
#[test]
fn explicit_unit_factors_reproduce_committed_baselines() {
    let corpus = tmp_dir("unit_factors");
    for entry in std::fs::read_dir(repo_path("scenarios")).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        let scenario = edgeward::scenario::Scenario::load(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let mut text = std::fs::read_to_string(&path).unwrap();
        if scenario.topology.is_homogeneous() {
            // make the implicit unit factors explicit, appending a
            // topology section when the file has none (the committed
            // files keep theirs last, so a bare append stays in-section)
            let t = &scenario.topology;
            if !text.contains("[scenario.topology]") {
                text.push_str(&format!(
                    "\n[scenario.topology]\nclouds = {}\nedges = {}\n",
                    t.clouds, t.edges
                ));
            }
            let ones = |n: usize| {
                vec!["1.0"; n].join(", ")
            };
            text.push_str(&format!(
                "cloud_speeds = [{}]\nedge_speeds = [{}]\n\
                 cloud_links = [{}]\nedge_links = [{}]\n",
                ones(t.clouds),
                ones(t.edges),
                ones(t.clouds),
                ones(t.edges)
            ));
        }
        std::fs::write(
            corpus.join(path.file_name().unwrap()),
            text,
        )
        .unwrap();
    }
    let result = Suite::discover(&corpus, seed7()).unwrap().run();
    let report = suite::check(&result, repo_path("baselines"));
    assert!(
        report.clean(),
        "explicit all-1.0 speed/link vectors drifted from the \
         committed goldens:\n{}",
        report.render()
    );
    std::fs::remove_dir_all(&corpus).unwrap();
}

#[test]
fn committed_corpus_runs_clean_against_committed_baselines() {
    let result = run_corpus();
    assert!(
        !result
            .cells
            .iter()
            .any(|c| matches!(c.status, CellStatus::Error { .. })),
        "no suite cell may error on the committed corpus"
    );
    // the oversized scenarios carry a typed exact-solver skip...
    assert!(result.cells.iter().any(|c| c.key.solver == "exact"
        && matches!(c.status, CellStatus::Skipped { .. })));
    // ...and every cell matches its committed golden
    let report = suite::check(&result, repo_path("baselines"));
    assert!(
        report.clean(),
        "committed baselines drifted:\n{}",
        report.render()
    );
}

#[test]
fn paper_trace_cells_reproduce_table_vii_bit_for_bit() {
    let result = run_corpus();
    let cell = |solver: &str| {
        let c = result
            .cells
            .iter()
            .find(|c| c.key.scenario == "paper" && c.key.solver == solver)
            .unwrap_or_else(|| panic!("paper × {solver} cell missing"));
        match &c.status {
            CellStatus::Ok(m) => m.clone(),
            other => panic!("paper × {solver}: {other:?}"),
        }
    };
    // the paper's published fixed-layer rows (cloud/edge label swap
    // documented in DESIGN.md §5)
    let cloud = cell("all-cloud");
    assert_eq!(cloud.unweighted_sum, 416);
    assert_eq!(cloud.makespan, 100);
    assert_eq!(cell("all-edge").unweighted_sum, 291);
    let device = cell("all-device");
    assert_eq!(device.unweighted_sum, 366);
    assert_eq!(device.makespan, 94);
    // ours never loses to a baseline row, and the optimum bounds it
    let ours = cell("tabu");
    for solver in ["per-job-optimal", "all-cloud", "all-edge", "all-device"]
    {
        assert!(ours.unweighted_sum <= cell(solver).unweighted_sum);
    }
    assert!(cell("exact").cost <= ours.cost);
}

#[test]
fn suite_results_json_is_byte_identical_across_runs() {
    let out = tmp_dir("determinism");
    let path_a = out.join("a.json");
    let path_b = out.join("b.json");
    run_corpus().write(path_a.to_str().unwrap()).unwrap();
    run_corpus().write(path_b.to_str().unwrap()).unwrap();
    let a = std::fs::read(&path_a).unwrap();
    let b = std::fs::read(&path_b).unwrap();
    assert!(!a.is_empty());
    assert_eq!(
        a, b,
        "same corpus + same seed must produce byte-identical \
         suite_results.json"
    );
    std::fs::remove_dir_all(&out).unwrap();
}

#[test]
fn bless_then_check_roundtrip_detects_exactly_the_mutation() {
    // a small private corpus so mutations don't race the shared one
    let corpus = tmp_dir("roundtrip_corpus");
    std::fs::write(
        corpus.join("mini.toml"),
        "[scenario]\narrival = \"poisson-ward\"\njobs = 6\nrate = 0.4\n\
         seed = 3\n",
    )
    .unwrap();
    std::fs::write(
        corpus.join("mini_diurnal.toml"),
        "[scenario]\narrival = \"diurnal-ward\"\njobs = 5\nrate = 0.3\n\
         amplitude = 0.7\nperiod = 30\nseed = 3\n",
    )
    .unwrap();
    let result = Suite::discover(&corpus, seed7())
        .unwrap()
        .run();

    // bless → check is clean
    let goldens = tmp_dir("roundtrip_goldens");
    let written = suite::bless(&result, &goldens).unwrap();
    assert_eq!(written, 2, "one baseline file per scenario");
    assert!(suite::check(&result, &goldens).clean());

    // a single drifted cost is reported as exactly one Drift
    let mut drifted = result.clone();
    let mutated = drifted
        .cells
        .iter_mut()
        .find_map(|c| match &mut c.status {
            CellStatus::Ok(m) => {
                m.cost += 1;
                Some(c.key.clone())
            }
            _ => None,
        })
        .expect("at least one ok cell");
    let drifted_goldens = tmp_dir("roundtrip_drifted");
    suite::bless(&drifted, &drifted_goldens).unwrap();
    let report = suite::check(&result, &drifted_goldens);
    assert_eq!(report.drifted(), 1, "{}", report.render());
    assert_eq!(report.failed(), 0, "{}", report.render());
    let drift_row = report
        .rows
        .iter()
        .find(|r| matches!(r.verdict, Verdict::Drift { .. }))
        .unwrap();
    assert_eq!(drift_row.key, mutated);
    match &drift_row.verdict {
        Verdict::Drift { field, .. } => assert_eq!(*field, "cost"),
        other => panic!("{other:?}"),
    }

    // a stale baseline cell (solver no longer produced) is a Fail
    let mut extra = result.clone();
    let mut phantom = result.cells[0].clone();
    phantom.key.solver = "phantom-solver".into();
    extra.cells.push(phantom);
    let stale_goldens = tmp_dir("roundtrip_stale");
    suite::bless(&extra, &stale_goldens).unwrap();
    let report = suite::check(&result, &stale_goldens);
    assert_eq!(report.failed(), 1, "{}", report.render());
    assert_eq!(report.drifted(), 0);

    // an orphan baseline file (its scenario was deleted/renamed) fails
    // the gate instead of passing silently
    let orphan_goldens = tmp_dir("roundtrip_orphan");
    suite::bless(&result, &orphan_goldens).unwrap();
    std::fs::write(
        orphan_goldens.join("ghost_ward.json"),
        "{\"cells\": [], \"scenario\": \"ghost_ward\"}\n",
    )
    .unwrap();
    let report = suite::check(&result, &orphan_goldens);
    assert_eq!(report.failed(), 1, "{}", report.render());
    assert!(
        report.render().contains("orphan baseline file"),
        "{}",
        report.render()
    );
    // re-blessing removes the orphan: bless + commit is the complete
    // scenario rename/delete workflow
    suite::bless(&result, &orphan_goldens).unwrap();
    assert!(!orphan_goldens.join("ghost_ward.json").exists());
    assert!(suite::check(&result, &orphan_goldens).clean());

    // a missing baseline directory fails every cell, not panics
    let report =
        suite::check(&result, tmp_dir("roundtrip_missing"));
    assert_eq!(report.failed(), result.cells.len());

    for d in [
        corpus,
        goldens,
        drifted_goldens,
        stale_goldens,
        orphan_goldens,
        tmp_dir("roundtrip_missing"),
    ] {
        let _ = std::fs::remove_dir_all(d);
    }
}

/// Fixed-seed diurnal-ward generation matches the committed
/// expectations (cross-checked against the independent oracle in
/// `python/tools/suite_oracle.py`).  If this test moves, either the RNG,
/// the thinning loop, or the jitter changed — all of which invalidate
/// every committed baseline.
#[test]
#[rustfmt::skip]
fn diurnal_ward_golden_job_lists() {
    let arrival = Arrival::DiurnalWard {
        jobs: 6,
        rate: 0.3,
        amplitude: 0.8,
        period: 40,
    };
    let expected_seed_11 = [
        Job { release: 15, weight: 2, proc_cloud: 3, trans_cloud: 31, proc_edge: 3, trans_edge: 5, proc_device: 11 },
        Job { release: 15, weight: 1, proc_cloud: 4, trans_cloud: 14, proc_edge: 7, trans_edge: 2, proc_device: 52 },
        Job { release: 17, weight: 1, proc_cloud: 9, trans_cloud: 20, proc_edge: 14, trans_edge: 5, proc_device: 52 },
        Job { release: 19, weight: 1, proc_cloud: 4, trans_cloud: 15, proc_edge: 6, trans_edge: 2, proc_device: 50 },
        Job { release: 26, weight: 2, proc_cloud: 4, trans_cloud: 73, proc_edge: 6, trans_edge: 17, proc_device: 23 },
        Job { release: 33, weight: 2, proc_cloud: 5, trans_cloud: 59, proc_edge: 6, trans_edge: 17, proc_device: 25 },
    ];
    let expected_seed_12 = [
        Job { release: 7, weight: 1, proc_cloud: 8, trans_cloud: 22, proc_edge: 9, trans_edge: 6, proc_device: 83 },
        Job { release: 7, weight: 1, proc_cloud: 4, trans_cloud: 14, proc_edge: 5, trans_edge: 2, proc_device: 50 },
        Job { release: 11, weight: 2, proc_cloud: 5, trans_cloud: 80, proc_edge: 5, trans_edge: 14, proc_device: 20 },
        Job { release: 17, weight: 2, proc_cloud: 5, trans_cloud: 47, proc_edge: 10, trans_edge: 10, proc_device: 15 },
        Job { release: 18, weight: 2, proc_cloud: 4, trans_cloud: 84, proc_edge: 5, trans_edge: 17, proc_device: 17 },
        Job { release: 19, weight: 1, proc_cloud: 3, trans_cloud: 12, proc_edge: 5, trans_edge: 2, proc_device: 43 },
    ];
    assert_eq!(arrival.generate(11), expected_seed_11);
    assert_eq!(arrival.generate(12), expected_seed_12);
}

/// Fixed-seed correlated-burst generation matches the committed
/// expectations (cross-checked against the independent oracle via
/// `python/tools/suite_oracle.py --print-goldens`).  Releases cluster
/// within `span` ticks of each parent event — the correlation the
/// process exists for — and any drift here invalidates the committed
/// metro goldens.
#[test]
#[rustfmt::skip]
fn correlated_burst_golden_job_lists() {
    let arrival = Arrival::CorrelatedBurst {
        events: 3,
        rate: 0.2,
        burst: 2,
        span: 5,
    };
    let expected_seed_11 = [
        Job { release: 2, weight: 1, proc_cloud: 3, trans_cloud: 9, proc_edge: 7, trans_edge: 2, proc_device: 59 },
        Job { release: 4, weight: 1, proc_cloud: 7, trans_cloud: 23, proc_edge: 10, trans_edge: 6, proc_device: 73 },
        Job { release: 6, weight: 2, proc_cloud: 3, trans_cloud: 31, proc_edge: 3, trans_edge: 7, proc_device: 13 },
        Job { release: 4, weight: 2, proc_cloud: 4, trans_cloud: 82, proc_edge: 4, trans_edge: 11, proc_device: 21 },
        Job { release: 11, weight: 2, proc_cloud: 4, trans_cloud: 34, proc_edge: 5, trans_edge: 5, proc_device: 11 },
        Job { release: 11, weight: 1, proc_cloud: 4, trans_cloud: 14, proc_edge: 6, trans_edge: 2, proc_device: 45 },
    ];
    let expected_seed_12 = [
        Job { release: 3, weight: 2, proc_cloud: 4, trans_cloud: 68, proc_edge: 4, trans_edge: 14, proc_device: 18 },
        Job { release: 5, weight: 2, proc_cloud: 5, trans_cloud: 46, proc_edge: 9, trans_edge: 9, proc_device: 17 },
        Job { release: 15, weight: 2, proc_cloud: 3, trans_cloud: 40, proc_edge: 3, trans_edge: 6, proc_device: 11 },
        Job { release: 13, weight: 1, proc_cloud: 4, trans_cloud: 12, proc_edge: 6, trans_edge: 2, proc_device: 57 },
        Job { release: 12, weight: 1, proc_cloud: 7, trans_cloud: 28, proc_edge: 11, trans_edge: 6, proc_device: 64 },
        Job { release: 15, weight: 2, proc_cloud: 4, trans_cloud: 29, proc_edge: 6, trans_edge: 6, proc_device: 10 },
    ];
    assert_eq!(arrival.generate(11), expected_seed_11);
    assert_eq!(arrival.generate(12), expected_seed_12);
}

#[test]
fn seed_override_changes_cells_but_not_the_paper_trace() {
    let corpus = tmp_dir("seed_override");
    std::fs::write(
        corpus.join("paper.toml"),
        "[scenario]\nname = \"paper\"\n",
    )
    .unwrap();
    std::fs::write(
        corpus.join("ward.toml"),
        "[scenario]\narrival = \"poisson-ward\"\njobs = 6\nrate = 0.4\n",
    )
    .unwrap();
    let run = |seed: u64| {
        Suite::discover(
            &corpus,
            SuiteConfig {
                seeds: vec![seed],
                solvers: vec!["greedy".into()],
                ..SuiteConfig::default()
            },
        )
        .unwrap()
        .run()
    };
    let a = run(7);
    let b = run(8);
    let by = |r: &edgeward::suite::SuiteResult, stem: &str| {
        match &r
            .cells
            .iter()
            .find(|c| c.key.scenario == stem)
            .unwrap()
            .status
        {
            CellStatus::Ok(m) => m.clone(),
            other => panic!("{other:?}"),
        }
    };
    // the paper trace is seed-independent; the generated ward is not
    assert_eq!(by(&a, "paper"), by(&b, "paper"));
    assert_ne!(by(&a, "ward"), by(&b, "ward"));
    std::fs::remove_dir_all(&corpus).unwrap();
}
