//! Metro regression harness, end to end: a 1-ward metro granted the
//! whole shared cloud is bit-for-bit the equivalent flat scenario, the
//! committed metros under `scenarios/metro/` run clean against the
//! committed goldens under `baselines/metro/`, global coordination is
//! never worse than ward-local planning, and the corpus covers the
//! features the metro tier exists to exercise.

use std::path::{Path, PathBuf};

use edgeward::metro::{self, Metro};
use edgeward::scenario::Scenario;

/// The committed corpus/goldens live at the repository root.  Cargo
/// runs integration tests from the package root, whose location
/// relative to the repository root depends on where the build harness
/// put the manifest — probe both.
fn repo_path(name: &str) -> PathBuf {
    for base in ["..", "."] {
        let p = Path::new(base).join(name);
        if p.is_dir() {
            return p;
        }
    }
    panic!(
        "committed {name}/ directory not found relative to {:?}",
        std::env::current_dir()
    )
}

fn committed_metros() -> Vec<(String, Metro)> {
    Metro::discover(repo_path("scenarios").join("metro"))
        .unwrap_or_else(|e| panic!("discovering scenarios/metro/: {e}"))
}

/// ISSUE 7 tentpole invariant: one ward granted the entire shared cloud
/// tier *is* the flat single-scenario model — same jobs, same topology
/// (shared factors included), same schedule, bit for bit.
#[test]
fn one_ward_metro_with_whole_cloud_is_the_flat_scenario() {
    let m = Metro::from_toml(
        "[metro]\nname = \"solo\"\nseed = 11\ncloud_replicas = 2\n\
         cloud_speeds = [2.0, 1.0]\ncloud_links = [1.0, 0.5]\n\n\
         [[metro.ward]]\nname = \"ward\"\narrival = \"poisson-ward\"\n\
         jobs = 7\nrate = 0.4\nedges = 2\nedge_speeds = [2.0, 0.5]\n",
    )
    .unwrap();
    let granted: Vec<usize> = vec![0, 1];
    let from_metro = m.ward_scenario(0, &granted).unwrap();
    let flat = Scenario::from_toml(
        "[scenario]\nname = \"ward\"\narrival = \"poisson-ward\"\n\
         jobs = 7\nrate = 0.4\nseed = 11\n\n[scenario.topology]\n\
         clouds = 2\nedges = 2\ncloud_speeds = [2.0, 1.0]\n\
         cloud_links = [1.0, 0.5]\nedge_speeds = [2.0, 0.5]\n",
    )
    .unwrap();
    assert_eq!(from_metro, flat, "metro ward != flat scenario");
    let a = from_metro.solve("tabu").unwrap();
    let b = flat.solve("tabu").unwrap();
    assert_eq!(a.assignment, b.assignment);
    assert_eq!(a.trace.entries, b.trace.entries);
    assert_eq!(from_metro.evaluate(&a), flat.evaluate(&b));
}

/// ISSUE 7 satellite: the coordinated plan is the best of the candidate
/// mechanisms, so it can never lose to every ward planning alone — the
/// price of ward-local decisions is non-negative on every committed
/// metro, and the whole outcome matches its committed golden
/// byte-for-byte at the canonical seed 7.
#[test]
fn committed_metros_run_clean_against_committed_goldens() {
    let metros = committed_metros();
    assert!(
        metros.len() >= 3,
        "corpus must hold at least 3 metros, found {}",
        metros.len()
    );
    let mut results = Vec::new();
    for (stem, m) in &metros {
        let out = m
            .solve_seeded(7)
            .unwrap_or_else(|e| panic!("{stem}: {e}"));
        assert!(
            out.coordinated_total <= out.local_total,
            "{stem}: coordination lost to ward-local planning \
             ({} > {})",
            out.coordinated_total,
            out.local_total
        );
        assert_eq!(
            out.price_of_ward_local,
            out.local_total - out.coordinated_total,
            "{stem}: price must be the local/coordinated gap"
        );
        results.push((stem.clone(), out));
    }
    let report = metro::check(
        &results,
        repo_path("baselines").join("metro"),
    );
    assert!(
        report.clean(),
        "committed metro goldens drifted:\n{}",
        report.render()
    );
}

/// The corpus exercises what the metro tier exists for: a surge ward
/// riding next to steady wards, heterogeneous ward links, the new
/// correlated-burst arrival, the weighted-tardiness objective, and at
/// least one metro where the cross-ward refinement actually runs.
#[test]
fn committed_metro_corpus_covers_required_features() {
    let metros = committed_metros();
    let all_wards: Vec<_> = metros
        .iter()
        .flat_map(|(_, m)| m.wards.iter())
        .collect();
    let arrivals: Vec<&str> =
        all_wards.iter().map(|w| w.arrival.key()).collect();
    for required in ["code-blue-surge", "correlated-burst"] {
        assert!(
            arrivals.contains(&required),
            "no committed metro has a {required} ward: {arrivals:?}"
        );
    }
    assert!(
        all_wards
            .iter()
            .any(|w| w.objective.key() == "weighted-tardiness"),
        "no committed metro has a weighted-tardiness ward"
    );
    assert!(
        all_wards.iter().any(|w| w
            .edge_links
            .iter()
            .chain(w.edge_speeds.iter())
            .any(|&f| f != 1.0)),
        "no committed metro has a heterogeneous ward"
    );
    assert!(
        metros.iter().any(|(_, m)| {
            m.refine && m.solve_seeded(7).unwrap().refined
        }),
        "no committed metro exercises cross-ward refinement"
    );
}

/// Every committed metro TOML round-trips through `to_value` + the TOML
/// emitter, and the solve is deterministic (same metro + same seed →
/// identical outcome object).
#[test]
fn committed_metros_roundtrip_and_are_deterministic() {
    for (stem, m) in committed_metros() {
        let mut root = edgeward::serialize::Value::object();
        root.set("metro", m.to_value());
        let text = edgeward::serialize::toml::emit(&root);
        let back = Metro::from_toml(&text)
            .unwrap_or_else(|e| panic!("{stem}: re-parse: {e}"));
        assert_eq!(back, m, "{stem}: TOML round-trip drifted");
        assert_eq!(
            m.solve_seeded(7).unwrap(),
            m.solve_seeded(7).unwrap(),
            "{stem}: solve must be deterministic"
        );
    }
}

/// Discovery is strict: a directory without metros is a typed error,
/// and a broken TOML names its file.
#[test]
fn discovery_errors_are_typed_and_name_the_file() {
    let dir = std::env::temp_dir().join("edgeward_metro_discovery");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let err = Metro::discover(&dir).unwrap_err();
    assert!(err.to_string().contains("no metro TOMLs"), "{err}");
    std::fs::write(
        dir.join("broken.toml"),
        "[metro]\ncloud_replicas = 0\n\n[[metro.ward]]\n",
    )
    .unwrap();
    let err = Metro::discover(&dir).unwrap_err();
    assert!(err.to_string().contains("broken.toml"), "{err}");
    std::fs::remove_dir_all(&dir).unwrap();
}
