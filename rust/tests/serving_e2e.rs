//! Integration: the full serving coordinator against real artifacts.

use edgeward::allocation::Calibration;
use edgeward::config::Environment;
use edgeward::coordinator::{
    live_calibration, Coordinator, Policy, ServeConfig,
};
use edgeward::device::Layer;
use edgeward::topology::Topology;
use edgeward::workload::Application;

fn have_artifacts() -> bool {
    let ok = std::path::Path::new("artifacts/manifest.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

fn fast_cfg(policy: Policy) -> ServeConfig {
    ServeConfig {
        patients: 3,
        requests_per_patient: 4,
        arrival_rate_hz: 10.0,
        policy,
        topology: Topology::paper(),
        batch_window_ms: 2,
        max_batch: 4,
        size_units: 64,
        time_scale: 0.01,
        emulate_compute: true,
        compute_scale: 1.0,
        app_mix: [1.0, 1.0, 1.0],
        ..ServeConfig::default()
    }
}

#[test]
fn serve_completes_all_requests() {
    if !have_artifacts() {
        return;
    }
    let env = Environment::paper();
    let cfg = fast_cfg(Policy::AlgorithmOne);
    let coord =
        Coordinator::new(env, Calibration::paper(), cfg, "artifacts").unwrap();
    let report = coord.run(5).unwrap();
    assert_eq!(report.completed, 12);
    assert_eq!(report.routed.iter().sum::<u64>(), 12);
    assert_eq!(report.metrics.total_requests, 12);
    assert!(report.metrics.throughput_rps > 0.0);
    // lane accounting covers every completion
    assert_eq!(report.lanes.len(), 3);
    assert_eq!(
        report.lanes.iter().map(|l| l.requests).sum::<u64>(),
        12
    );
}

#[test]
fn multi_edge_serving_completes_and_reports_utilization() {
    if !have_artifacts() {
        return;
    }
    // the acceptance scenario: edges = 2 wired through the coordinator —
    // four lanes (CC0, ES0, ES1, ED), round-robin so every lane sees
    // deterministic traffic
    let env = Environment::paper();
    let mut cfg = fast_cfg(Policy::RoundRobin);
    cfg.topology = Topology::new(1, 2);
    cfg.patients = 4;
    cfg.requests_per_patient = 4; // 16 = 4 full round-robin cycles
    let coord =
        Coordinator::new(env, Calibration::paper(), cfg, "artifacts").unwrap();
    let report = coord.run(21).unwrap();
    assert_eq!(report.completed, 16);
    assert_eq!(report.topology, Topology::new(1, 2));
    assert_eq!(report.lanes.len(), 4);
    // round-robin over 4 lanes × 16 requests = 4 per lane
    for lane in &report.lanes {
        assert_eq!(
            lane.requests,
            4,
            "lane {} got {} requests",
            lane.machine.label(),
            lane.requests
        );
        // emulated busy time over real wall time: non-negative and
        // finite (it can exceed 1 when time_scale compresses the clock)
        assert!(
            lane.utilization >= 0.0 && lane.utilization.is_finite(),
            "lane {} utilization {}",
            lane.machine.label(),
            lane.utilization
        );
    }
    // per-class routing: 4 cloud, 8 edge (two replicas), 4 device
    assert_eq!(report.routed, [4, 8, 4]);
    // the JSON report carries the per-lane rows
    let v = report.to_value();
    let rendered = v.to_string_pretty();
    assert!(rendered.contains("ES1"), "{rendered}");
}

#[test]
fn heterogeneous_lanes_serve_and_report_speeds() {
    if !have_artifacts() {
        return;
    }
    // a big (×2) and a little (×0.5) edge box: the run completes and the
    // per-lane report carries each replica's speed factor
    let env = Environment::paper();
    let mut cfg = fast_cfg(Policy::RoundRobin);
    cfg.topology =
        Topology::with_speeds(1, 2, None, Some(vec![2.0, 0.5]))
            .unwrap();
    let coord =
        Coordinator::new(env, Calibration::paper(), cfg, "artifacts")
            .unwrap();
    let report = coord.run(31).unwrap();
    assert_eq!(report.completed, 12);
    assert_eq!(report.lanes.len(), 4);
    let by_label = |label: &str| {
        report
            .lanes
            .iter()
            .find(|l| l.machine.label() == label)
            .unwrap_or_else(|| panic!("no lane {label}"))
    };
    assert_eq!(by_label("ES0").speed, 2.0);
    assert_eq!(by_label("ES1").speed, 0.5);
    assert_eq!(by_label("CC0").speed, 1.0);
    let v = report.to_value().to_string_pretty();
    assert!(v.contains("\"speed\""), "{v}");
}

#[test]
fn link_heterogeneous_lanes_serve_and_report_links() {
    if !have_artifacts() {
        return;
    }
    // a wired (×1) and a Wi-Fi (×0.5) edge box: the run completes, each
    // replica's delay queue uses its own link-scaled transmission, and
    // the per-lane report carries the link factor
    let env = Environment::paper();
    let mut cfg = fast_cfg(Policy::RoundRobin);
    cfg.topology =
        Topology::with_links(1, 2, None, Some(vec![1.0, 0.5]))
            .unwrap();
    let coord =
        Coordinator::new(env, Calibration::paper(), cfg, "artifacts")
            .unwrap();
    let report = coord.run(41).unwrap();
    assert_eq!(report.completed, 12);
    assert_eq!(report.lanes.len(), 4);
    let by_label = |label: &str| {
        report
            .lanes
            .iter()
            .find(|l| l.machine.label() == label)
            .unwrap_or_else(|| panic!("no lane {label}"))
    };
    assert_eq!(by_label("ES0").link, 1.0);
    assert_eq!(by_label("ES1").link, 0.5);
    assert_eq!(by_label("CC0").link, 1.0);
    assert_eq!(by_label("ES0").speed, 1.0);
    let v = report.to_value().to_string_pretty();
    assert!(v.contains("\"link\""), "{v}");
}

#[test]
fn least_loaded_policy_serves_all_requests() {
    if !have_artifacts() {
        return;
    }
    let env = Environment::paper();
    let mut cfg = fast_cfg(Policy::LeastLoaded);
    cfg.topology = Topology::new(1, 2);
    let coord =
        Coordinator::new(env, Calibration::paper(), cfg, "artifacts").unwrap();
    let report = coord.run(22).unwrap();
    assert_eq!(report.completed, 12);
    assert_eq!(
        report.lanes.iter().map(|l| l.requests).sum::<u64>(),
        12
    );
}

#[test]
fn fixed_policies_route_everything_to_their_layer() {
    if !have_artifacts() {
        return;
    }
    let env = Environment::paper();
    for (policy, idx) in [
        (Policy::FixedCloud, 0usize),
        (Policy::FixedEdge, 1),
        (Policy::FixedDevice, 2),
    ] {
        let coord = Coordinator::new(
            env.clone(),
            Calibration::paper(),
            fast_cfg(policy),
            "artifacts",
        )
        .unwrap();
        let report = coord.run(6).unwrap();
        assert_eq!(report.routed[idx], 12, "{policy:?}");
        for (i, &n) in report.routed.iter().enumerate() {
            if i != idx {
                assert_eq!(n, 0, "{policy:?} leaked to layer {i}");
            }
        }
    }
}

#[test]
fn algorithm1_routing_respects_table_v() {
    if !have_artifacts() {
        return;
    }
    // with the paper calibration, WL mix routes per Table V: breath+
    // phenotype to edge, mortality to device, never cloud
    let env = Environment::paper();
    let coord = Coordinator::new(
        env,
        Calibration::paper(),
        fast_cfg(Policy::AlgorithmOne),
        "artifacts",
    )
    .unwrap();
    let report = coord.run(7).unwrap();
    assert_eq!(report.routed[0], 0, "cloud should never win Table V");
    assert!(report.routed[1] > 0 || report.routed[2] > 0);
}

#[test]
fn batching_happens_on_shared_layers() {
    if !have_artifacts() {
        return;
    }
    let env = Environment::paper();
    let mut cfg = fast_cfg(Policy::FixedEdge);
    cfg.patients = 4;
    cfg.requests_per_patient = 6;
    cfg.arrival_rate_hz = 200.0; // burst: everything lands in one window
    cfg.app_mix = [1.0, 0.0, 0.0]; // one app → batchable
    cfg.batch_window_ms = 50;
    let coord =
        Coordinator::new(env, Calibration::paper(), cfg, "artifacts").unwrap();
    let report = coord.run(8).unwrap();
    let edge = &report.metrics.per_layer["ES"];
    assert_eq!(edge.requests, 24);
    assert!(
        edge.mean_batch > 1.5,
        "expected batching under burst load, mean batch = {}",
        edge.mean_batch
    );
}

#[test]
fn compute_scale_slows_processing() {
    if !have_artifacts() {
        return;
    }
    let env = Environment::paper();
    let mut cfg = fast_cfg(Policy::FixedDevice);
    let coord = Coordinator::new(
        env.clone(),
        Calibration::paper(),
        cfg.clone(),
        "artifacts",
    )
    .unwrap();
    let base = coord.run(9).unwrap();
    cfg.compute_scale = 50.0;
    let coord =
        Coordinator::new(env, Calibration::paper(), cfg, "artifacts").unwrap();
    let scaled = coord.run(9).unwrap();
    let p = |r: &edgeward::coordinator::ServeReport| {
        r.metrics.per_layer["ED"].processing.mean
    };
    assert!(
        p(&scaled) > p(&base) * 10.0,
        "processing {} vs {}",
        p(&scaled),
        p(&base)
    );
}

#[test]
fn live_calibration_produces_usable_model() {
    if !have_artifacts() {
        return;
    }
    let env = Environment::paper();
    let cfg = fast_cfg(Policy::AlgorithmOne);
    let calib = live_calibration(&env, &cfg, "artifacts", 11).unwrap();
    for app in Application::ALL {
        let c = calib.for_app(app);
        assert!(c.lambda2 > 0.0, "{app}");
        assert!(c.lambda1.cloud >= 0.0 && c.lambda1.edge >= 0.0);
        assert_eq!(*c.lambda1.get(Layer::Device), 0.0);
    }
}

#[test]
fn serve_deterministic_routing() {
    if !have_artifacts() {
        return;
    }
    // same seed → same routing decisions (latencies vary, routing doesn't)
    let env = Environment::paper();
    let mk = || {
        Coordinator::new(
            env.clone(),
            Calibration::paper(),
            fast_cfg(Policy::RoundRobin),
            "artifacts",
        )
        .unwrap()
        .run(123)
        .unwrap()
    };
    let a = mk();
    let b = mk();
    assert_eq!(a.routed, b.routed);
}
